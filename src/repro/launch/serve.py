"""Serving launcher — the LM demo and the networked mapping service.

LM prefill/decode demo (the original path):

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --batch 4 --prompt-len 32 --max-new 32

Networked mapping service (HTTP frontend over a MappingService):

    PYTHONPATH=src python -m repro.launch.serve --serve-maps \
        --backend engine --port 8000 --max-batch 8 --max-wait 0.01

``--backend`` picks the inference backend behind the service: ``mock``
(paper replay bank), ``engine`` (real prefill/decode on the in-repo smoke
transformer — see ``core/backends.EngineBackend``), or ``ollama`` (live
local GGUF models).  Derive requests for the same model are admitted
through a batching queue (``--max-batch`` / ``--max-wait`` /
``--max-pending``); same-cell requests coalesce inside the service.

By default the service runs on the asyncio event-loop frontend
(``serving/aio.py``) and — for the engine backend — continuous batching
(``--decode-slots`` / ``--admission-timeout``): new derives join in-flight
decode batches at the next step boundary.  ``--no-async`` restores the
threaded ``ThreadingHTTPServer`` + gather-then-drain batching.
"""
from __future__ import annotations

import argparse
import functools
import os
import time


def _backend_factory(args):
    from repro.core import backends

    if args.backend == "mock":
        return backends.MockLLMBackend
    if args.backend == "engine":
        return functools.partial(
            backends.EngineBackend, arch=args.arch or "yi-6b",
            max_new_tokens=args.max_new, temperature=args.temperature)
    if args.backend == "ollama":
        return backends.OllamaBackend
    raise ValueError(f"unknown backend {args.backend!r}")


def _store_from_args(args):
    """Assemble the tiered store from the CLI knobs, falling back to the
    documented env surface (REPRO_STORE_TTL / REPRO_STORE_MAX_BYTES /
    REPRO_MEMORY_ENTRIES / REPRO_PEERS) for any flag left unset — the
    flag/env pairs in the README stay equivalent.  None = store off,
    coalescing-only degradation."""
    from repro.core.store import build_store, env_knobs, split_peers

    knobs = env_knobs()
    if args.store_ttl is not None:
        knobs["ttl_seconds"] = args.store_ttl
    if args.store_max_bytes is not None:
        knobs["max_bytes"] = args.store_max_bytes
    if args.memory_entries is not None:
        knobs["memory_entries"] = args.memory_entries
    if args.peers is not None:
        knobs["peers"] = split_peers(args.peers)
    return build_store(**knobs)


def _pick(value, default):
    """``value`` unless unset — unlike ``or`` this keeps legitimate
    zeros (epsilon=0, gossip fanout 0 = auto)."""
    return default if value is None else value


def _env(name: str, flag_value, cast=str):
    """Flag wins; else the REPRO_CLUSTER_* env var; else None."""
    if flag_value is not None:
        return flag_value
    raw = os.environ.get(name, "").strip()
    if not raw:
        return None
    try:
        return cast(raw)
    except ValueError:
        import warnings

        warnings.warn(f"ignoring malformed {name}={raw!r}", stacklevel=2)
        return None


def _cluster_from_args(args, server):
    """Join a consistent-hash fleet when --cluster-seed (or
    REPRO_CLUSTER_SEED) names at least one live node.  The first node of a
    fleet seeds from its own URL; everyone else names any existing member.
    Returns the started ClusterMembership, or None (standalone — PR 4
    behavior, byte-identical)."""
    from repro.core.store import split_peers
    from repro.serving.cluster import (
        DEFAULT_REPLICAS, DEFAULT_VNODES, ClusterMembership,
    )

    seeds = split_peers(_env("REPRO_CLUSTER_SEED", args.cluster_seed))
    if not seeds:
        return None
    self_url = _env("REPRO_CLUSTER_ADVERTISE", args.advertise_url) \
        or server.url
    cluster = ClusterMembership(
        self_url=self_url,
        seeds=seeds,
        vnodes=_env("REPRO_CLUSTER_VNODES", args.vnodes, int)
        or DEFAULT_VNODES,
        replicas=_env("REPRO_CLUSTER_REPLICAS", args.replicas, int)
        or DEFAULT_REPLICAS,
        heartbeat_interval=_env("REPRO_CLUSTER_HEARTBEAT",
                                args.heartbeat_interval, float) or 1.0,
        sync_interval=_env("REPRO_CLUSTER_SYNC_INTERVAL",
                           args.sync_interval, float) or 5.0,
        placement=_env("REPRO_CLUSTER_PLACEMENT", args.placement) or "ring",
        weight=_pick(_env("REPRO_CLUSTER_WEIGHT", args.weight, float), 1.0),
        gossip_fanout=_pick(
            _env("REPRO_CLUSTER_FANOUT", args.gossip_fanout, int), 0),
    )
    return server.attach_cluster(cluster)


def _router_from_args(args):
    """Build the per-node request router from the CLI knobs, falling back
    to the REPRO_ROUTER_* env surface for any flag left unset."""
    from repro.serving.router import RequestRouter

    return RequestRouter(
        policy=_env("REPRO_ROUTER_POLICY", args.route_policy) or "loaded",
        max_pending=args.max_pending,
        ttl=_pick(_env("REPRO_ROUTER_TTL", args.router_ttl, float), 30.0),
        epsilon=_pick(
            _env("REPRO_ROUTER_EPSILON", args.router_epsilon, float), 0.05),
        depth_penalty_ms=_pick(
            _env("REPRO_ROUTER_DEPTH_PENALTY",
                 args.router_depth_penalty, float), 5.0),
    )


def serve_maps(args) -> None:
    """Boot the full stack: backend -> batching queue -> MappingService ->
    HTTP frontend (-> cluster membership), then serve until interrupted.

    ``--async`` (the default) serves from the asyncio event-loop frontend
    and, for the engine backend, drives generation through the continuous
    batcher (step-interleaved cohorts, ``--decode-slots``); ``--no-async``
    falls back to the threaded server + gather-then-drain batching."""
    from repro.core import compile_cache
    from repro.serving import (
        AsyncMappingHTTPServer, MappingHTTPServer, MappingService,
        batching_factory, continuous_factory,
    )

    # evaluation-plane knobs (flags win; REPRO_COMPILE_CACHE_* env fallback
    # is read inside configure_default/default_compile_cache)
    if args.compile_cache_entries is not None \
            or args.compile_cache_dir is not None:
        compile_cache.configure_default(
            max_entries=args.compile_cache_entries,
            persist_dir=args.compile_cache_dir)
    cc = compile_cache.default_compile_cache()

    if args.use_async and args.backend == "engine":
        # continuous batching: new derives join in-flight decodes at the
        # next step boundary instead of waiting for the batch to drain
        factory = continuous_factory(
            _backend_factory(args), decode_slots=args.decode_slots,
            max_pending=args.max_pending,
            admission_timeout=args.admission_timeout)
    else:
        factory = batching_factory(
            _backend_factory(args), max_batch=args.max_batch,
            max_wait=args.max_wait, max_pending=args.max_pending)
    service = MappingService(store=_store_from_args(args),
                             backend_factory=factory,
                             n_validate=args.n_validate)
    router = _router_from_args(args)
    serve_delay = _pick(_env("REPRO_SLOW_SERVE", args.slow_serve, float),
                        0.0)
    wire_entries = _pick(_env("REPRO_WIRE_CACHE_ENTRIES",
                              args.wire_cache_entries, int), 256)
    if args.use_async:
        server = AsyncMappingHTTPServer(
            service, host=args.host, port=args.port,
            max_pending=args.max_pending,
            observability=args.observability,
            router=router, serve_delay=serve_delay,
            wire_cache_entries=wire_entries)
        server.start()  # bind + loop up before cluster membership probes
    else:
        server = MappingHTTPServer(service, host=args.host, port=args.port,
                                   observability=args.observability,
                                   router=router, serve_delay=serve_delay,
                                   wire_cache_entries=wire_entries)
    cluster = _cluster_from_args(args, server)
    store = service.store
    if store is None:
        desc = "off"
    else:
        mem = store.memory.max_entries if store.memory is not None else 0
        peers = store.peer.peers if store.peer is not None else []
        disk = (f"{store.root} (ttl={store.disk.ttl_seconds}, "
                f"max_bytes={store.disk.max_bytes})"
                if store.disk is not None else "diskless")
        desc = f"{disk} memory={mem} entries, peers={peers or 'none'}"
    mode = "async" if args.use_async else "threaded"
    print(f"mapping service on {server.url}  "
          f"(backend={args.backend}, frontend={mode}, store={desc})")
    print(f"observability: tracing={'on' if args.observability else 'off'} "
          f"(X-Repro-Trace-Id; GET /v1/trace/<id>), metrics=json+prometheus "
          f"(GET /metrics?format=prometheus)")
    if args.use_async and args.backend == "engine":
        print(f"continuous batching: decode_slots={args.decode_slots} "
              f"admission_timeout={args.admission_timeout}s")
    if cc is None:
        print("compile cache: off")
    else:
        print(f"compile cache: {cc.max_entries} entries, "
              f"persist={cc.persist_dir or 'off'}")
    print(f"evaluate wire: binary framing via 'Accept: "
          f"application/x-repro-binary' or ?format=binary, "
          f"response LRU={wire_entries} entries")
    if cluster is not None:
        print(f"cluster: self={cluster.self_url} replicas="
              f"{cluster.replicas} vnodes={cluster.vnodes} "
              f"placement={cluster.placement} weight={cluster.weight} "
              f"gossip_fanout={cluster.gossip_fanout or 'auto'} "
              f"heartbeat={cluster.heartbeat_interval}s "
              f"sync={cluster.sync_interval}s "
              f"peers_up={cluster.live_peers() or 'none'}")
    print(f"router: policy={router.policy} epsilon={router.selector.epsilon} "
          f"ttl={router.queue.ttl}s max_pending={router.queue.capacity}")
    if serve_delay > 0:
        print(f"CHAOS: --slow-serve active, every derive sleeps "
              f"{serve_delay}s before serving")
    print("endpoints: POST /v1/derive  POST /v1/evaluate  "
          "GET|DELETE /v1/artifact/<key>  "
          "POST /v1/grid  GET /v1/store/stats  GET /v1/cluster  "
          "GET /v1/replicate/manifest  GET|POST /v1/replicate/<key>  "
          "GET /v1/trace/<id>  GET /v1/traces  GET /healthz  GET /metrics")
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        if cluster is not None:
            cluster.close()
        if args.use_async:
            server.close()
        else:
            server.httpd.server_close()


def lm_demo(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_smoke_config
    from repro.models import transformer as T
    from repro.models.common import count_params
    from repro.serving.engine import generate

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(max_seq=args.prompt_len + args.max_new)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    print(f"arch={cfg.arch_id} params={count_params(params):,}")

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)
    extra = None
    if cfg.family == "vlm":
        extra = jax.random.normal(
            key, (args.batch, cfg.vision_seq, cfg.d_model)) * 0.1
    if cfg.family == "audio":
        extra = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model)) * 0.1

    t0 = time.time()
    res = generate(params, cfg, prompts, args.max_new, extra=extra,
                   temperature=args.temperature)
    dt = time.time() - t0
    total_new = res.steps * args.batch
    print(f"generated {res.steps} steps x {args.batch} seqs in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s incl. compile)")
    print("sample:", res.tokens[0, args.prompt_len:args.prompt_len + 16].tolist())


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None,
                   help="model arch (LM demo; also the engine backend's "
                        "smoke config, default yi-6b)")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--max-new", type=int, default=32)
    p.add_argument("--temperature", type=float, default=0.0)
    # networked mapping service
    p.add_argument("--serve-maps", action="store_true",
                   help="serve mapping derivations over HTTP instead of "
                        "running the LM demo")
    p.add_argument("--backend", choices=("mock", "engine", "ollama"),
                   default="mock", help="inference backend for --serve-maps")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8000)
    p.add_argument("--n-validate", type=int, default=100_000,
                   help="ground-truth points per served validation")
    p.add_argument("--max-batch", type=int, default=8,
                   help="max derive requests per batched backend call")
    p.add_argument("--max-wait", type=float, default=0.01,
                   help="seconds the batcher waits to fill a batch")
    p.add_argument("--max-pending", type=int, default=256,
                   help="admission queue depth (beyond this: HTTP 503)")
    p.add_argument("--observability", default=True,
                   action=argparse.BooleanOptionalAction,
                   help="per-request tracing (X-Repro-Trace-Id propagation "
                        "+ /v1/trace endpoints); --no-observability turns "
                        "tracing off (metrics always stay on)")
    p.add_argument("--async", dest="use_async", default=True,
                   action=argparse.BooleanOptionalAction,
                   help="serve from the asyncio event-loop frontend with "
                        "continuous batching for the engine backend "
                        "(--no-async falls back to the threaded server + "
                        "gather-then-drain batching)")
    p.add_argument("--decode-slots", type=int, default=8,
                   help="continuous batching: max requests decoding "
                        "concurrently across cohorts (engine backend, "
                        "async mode)")
    p.add_argument("--admission-timeout", type=float, default=30.0,
                   metavar="SECONDS",
                   help="continuous batching: a request waiting longer than "
                        "this for a free decode slot fails with HTTP 504")
    # artifact-store lifecycle (see core/store.py)
    p.add_argument("--store-ttl", type=float, default=None, metavar="SECONDS",
                   help="evict records idle longer than this (default: never)")
    p.add_argument("--store-max-bytes", type=int, default=None,
                   metavar="BYTES",
                   help="disk budget; least-recently-accessed records are "
                        "evicted past it (default: unbounded)")
    p.add_argument("--memory-entries", type=int, default=None,
                   help="LRU hot-tier capacity in records (0 disables the "
                        "memory tier; default 256)")
    p.add_argument("--peers", default=None, metavar="URL[,URL...]",
                   help="static sibling servers to replicate with (PR 4 "
                        "broadcast mesh; superseded by --cluster-seed)")
    # evaluation plane (see core/compile_cache.py + serving/evaluate.py)
    p.add_argument("--compile-cache-entries", type=int, default=None,
                   help="compiled-executable LRU capacity for /v1/evaluate "
                        "(0 disables; default 128) "
                        "[REPRO_COMPILE_CACHE_ENTRIES]")
    p.add_argument("--compile-cache-dir", default=None, metavar="DIR",
                   help="persist serialized executables here so a restarted "
                        "server skips re-tracing (best effort — falls back "
                        "to in-memory when the jaxlib can't round-trip) "
                        "[REPRO_COMPILE_CACHE_DIR]")
    p.add_argument("--wire-cache-entries", type=int, default=None,
                   help="encoded evaluate-response LRU capacity (binary and "
                        "JSON blobs; 0 disables; default 256) "
                        "[REPRO_WIRE_CACHE_ENTRIES]")
    # consistent-hash sharded fleet (see serving/cluster.py); every flag
    # falls back to its REPRO_CLUSTER_* env var
    p.add_argument("--cluster-seed", default=None, metavar="URL[,URL...]",
                   help="join a sharded fleet by asking these live nodes "
                        "for the membership view (the first node of a "
                        "fleet seeds from its own URL) "
                        "[REPRO_CLUSTER_SEED]")
    p.add_argument("--replicas", type=int, default=None,
                   help="copies of each record across the fleet "
                        "(default 2) [REPRO_CLUSTER_REPLICAS]")
    p.add_argument("--vnodes", type=int, default=None,
                   help="virtual nodes per server on the hash ring "
                        "(default 64) [REPRO_CLUSTER_VNODES]")
    p.add_argument("--sync-interval", type=float, default=None,
                   metavar="SECONDS",
                   help="anti-entropy repair cadence (default 5.0) "
                        "[REPRO_CLUSTER_SYNC_INTERVAL]")
    p.add_argument("--heartbeat-interval", type=float, default=None,
                   metavar="SECONDS",
                   help="membership probe cadence (default 1.0) "
                        "[REPRO_CLUSTER_HEARTBEAT]")
    p.add_argument("--advertise-url", default=None, metavar="URL",
                   help="URL peers should reach this node at (default "
                        "http://HOST:PORT — set this when binding 0.0.0.0) "
                        "[REPRO_CLUSTER_ADVERTISE]")
    p.add_argument("--placement", choices=("ring", "rendezvous"),
                   default=None,
                   help="key->owner placement: weighted consistent-hash "
                        "ring (default) or rendezvous hashing "
                        "[REPRO_CLUSTER_PLACEMENT]")
    p.add_argument("--weight", type=float, default=None,
                   help="this node's capacity weight — scales its share "
                        "of the keyspace (default 1.0) "
                        "[REPRO_CLUSTER_WEIGHT]")
    p.add_argument("--gossip-fanout", type=int, default=None,
                   help="peers probed per heartbeat round: N>0 caps at N, "
                        "0 = auto ceil(log2(fleet))+2 (default), "
                        "negative = probe everyone [REPRO_CLUSTER_FANOUT]")
    # load-aware request router (see serving/router.py); every flag falls
    # back to its REPRO_ROUTER_* env var
    p.add_argument("--route-policy", choices=("loaded", "static"),
                   default=None,
                   help="replica selection: 'loaded' ranks owners by EWMA "
                        "latency + advertised queue depth (default); "
                        "'static' keeps placement order "
                        "[REPRO_ROUTER_POLICY]")
    p.add_argument("--router-ttl", type=float, default=None,
                   metavar="SECONDS",
                   help="queued forwards older than this expire instead of "
                        "dispatching (default 30.0) [REPRO_ROUTER_TTL]")
    p.add_argument("--router-epsilon", type=float, default=None,
                   help="epsilon-greedy exploration rate: probability a "
                        "non-best replica is promoted so cold replicas get "
                        "re-measured (default 0.05) [REPRO_ROUTER_EPSILON]")
    p.add_argument("--router-depth-penalty", type=float, default=None,
                   metavar="MS",
                   help="cost penalty per advertised queued request when "
                        "ranking replicas (default 5.0 ms) "
                        "[REPRO_ROUTER_DEPTH_PENALTY]")
    p.add_argument("--slow-serve", type=float, default=None,
                   metavar="SECONDS",
                   help="chaos knob: sleep this long before serving every "
                        "derive — makes this replica artificially slow so "
                        "load-aware routing can be demonstrated "
                        "[REPRO_SLOW_SERVE]")
    args = p.parse_args()

    if args.serve_maps:
        serve_maps(args)
    else:
        if not args.arch:
            p.error("--arch is required for the LM demo "
                    "(or pass --serve-maps)")
        lm_demo(args)


if __name__ == "__main__":
    main()
