"""Serving launcher — batched prefill + decode demo.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --batch 4 --prompt-len 32 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import transformer as T
from repro.models.common import count_params
from repro.serving.engine import generate


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--max-new", type=int, default=32)
    p.add_argument("--temperature", type=float, default=0.0)
    args = p.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(max_seq=args.prompt_len + args.max_new)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    print(f"arch={cfg.arch_id} params={count_params(params):,}")

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)
    extra = None
    if cfg.family == "vlm":
        extra = jax.random.normal(
            key, (args.batch, cfg.vision_seq, cfg.d_model)) * 0.1
    if cfg.family == "audio":
        extra = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model)) * 0.1

    t0 = time.time()
    res = generate(params, cfg, prompts, args.max_new, extra=extra,
                   temperature=args.temperature)
    dt = time.time() - t0
    total_new = res.steps * args.batch
    print(f"generated {res.steps} steps x {args.batch} seqs in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s incl. compile)")
    print("sample:", res.tokens[0, args.prompt_len:args.prompt_len + 16].tolist())


if __name__ == "__main__":
    main()
