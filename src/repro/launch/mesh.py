"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (jax locks the device count on first backend init, and the
dry-run must set XLA_FLAGS before that happens).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Mesh over whatever devices exist (tests / CPU runs)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
