"""Training launcher — end-to-end driver usable on CPU (reduced configs) and
on real TPU topologies (full configs; same code path as the dry-run).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.distribution import sharding as shd
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import transformer as T
from repro.models.common import count_params
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt
from repro.train.data import DataConfig, SyntheticLM
from repro.train.fault_tolerance import ResilientLoop, StepWatchdog
from repro.train.train_step import TrainConfig, make_train_step


def build(cfg, mesh, tcfg: TrainConfig, seed: int = 0):
    """Init sharded params + opt state and the jitted train step."""
    with shd.use_sharding(mesh):
        param_shapes = jax.eval_shape(
            lambda k: T.init_params(k, cfg), jax.random.PRNGKey(seed))
        p_sh = shd.param_sharding(T.param_specs(cfg), param_shapes, mesh)
        params = jax.jit(
            lambda k: T.init_params(k, cfg), out_shardings=p_sh
        )(jax.random.PRNGKey(seed))
        o_logical = opt.state_specs(T.param_specs(cfg))
        o_shapes = jax.eval_shape(opt.init_state, params)
        o_sh = shd.param_sharding(o_logical, o_shapes, mesh)
        opt_state = jax.jit(opt.init_state, out_shardings=o_sh)(params)
        step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    return params, opt_state, step


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true",
                   help="reduced same-family config (CPU-runnable)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--production-mesh", action="store_true")
    p.add_argument("--d-model", type=int, default=0,
                   help="override width (e.g. ~100M-param runs)")
    p.add_argument("--n-layers", type=int, default=0)
    p.add_argument("--d-ff", type=int, default=0)
    args = p.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    over = {"max_seq": args.seq}
    if args.d_model:
        over["d_model"] = args.d_model
    if args.n_layers:
        over["n_layers"] = args.n_layers
    if args.d_ff:
        over["d_ff"] = args.d_ff
    cfg = cfg.replace(**over)

    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())
    tcfg = TrainConfig(
        optimizer=opt.OptimizerConfig(lr=args.lr, total_steps=args.steps,
                                      warmup_steps=max(args.steps // 20, 5)),
        microbatches=args.microbatches,
    )
    params, opt_state, step = build(cfg, mesh, tcfg)
    n = count_params(params)
    print(f"arch={cfg.arch_id} params={n:,} mesh={dict(mesh.shape)}")

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch))

    def batch_fn(step_idx: int):
        b = data.batch_at(step_idx)
        if cfg.family in ("vlm", "audio"):
            n_extra = cfg.vision_seq if cfg.family == "vlm" else cfg.encoder_seq
            rng = np.random.default_rng(step_idx)
            b["extra"] = rng.standard_normal(
                (args.batch, n_extra, cfg.d_model), dtype=np.float32) * 0.1
        return jax.tree.map(jnp.asarray, b)

    start = 0
    if args.resume:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            restored, _ = ckpt.restore(
                args.ckpt_dir, last,
                {"params": params, "opt_state": opt_state})
            params, opt_state = restored["params"], restored["opt_state"]
            start = last
            print(f"resumed from step {start}")

    def run_step(params, opt_state, batch):
        with shd.use_sharding(mesh):
            return step(params, opt_state, batch)

    loop = ResilientLoop(
        step_fn=run_step, batch_fn=batch_fn, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, watchdog=StepWatchdog())
    t0 = time.time()
    params, opt_state, info = loop.run(
        params, opt_state, start, args.steps, log_every=args.log_every)
    dt = time.time() - t0
    print(f"done: {info['final_step'] - start} steps in {dt:.1f}s "
          f"({dt / max(info['final_step'] - start, 1):.2f} s/step), "
          f"restores={info['restores']}, "
          f"median_step={loop.watchdog.median:.3f}s")
    final = {k: float(v) for k, v in (info["metrics"] or {}).items()}
    print("final metrics:", final)


if __name__ == "__main__":
    main()
