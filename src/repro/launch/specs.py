"""ShapeDtypeStruct stand-ins + logical shardings for every dry-run cell.

``input_specs(cfg, shape)`` builds the exact abstract inputs each step kind
lowers against (no device allocation), with NamedShardings attached so
``jax.jit(...).lower(*specs)`` sees the production distribution:

  train_*    -> train_step(params, opt_state, batch)
  prefill_*  -> prefill(params, tokens, extra)
  decode_* / long_* -> decode_step(params, token, cache, extra)
       (one new token against a seq_len KV cache)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.distribution import sharding as shd
from repro.models import transformer as T
from repro.train import optimizer as opt


# ---------------------------------------------------------------------------
# activation rule sets per shape kind
# ---------------------------------------------------------------------------


def act_rules_for(shape: ShapeSpec) -> dict:
    if shape.kind == "train" or shape.kind == "prefill":
        return dict(shd.ACT_RULES)
    if shape.name == "long_500k":  # batch=1: sequence parallelism instead
        return {**shd.ACT_RULES, "batch": None, "kv_seq": ("data", "model")}
    # decode: shard the cache's sequence dim over the tensor axis
    return {**shd.ACT_RULES, "kv_seq": "model"}


# ---------------------------------------------------------------------------
# logical spec trees for caches (mirrors transformer.init_cache)
# ---------------------------------------------------------------------------


def _gqa_cache_specs(quant: bool = False):
    s = {"k": ("layers", "batch", "kv_seq", "kv_heads", None),
         "v": ("layers", "batch", "kv_seq", "kv_heads", None),
         "idx": ("layers",)}
    if quant:
        s["k_scale"] = ("layers", "batch", "kv_seq", "kv_heads", None)
        s["v_scale"] = ("layers", "batch", "kv_seq", "kv_heads", None)
    return s


def _mla_cache_specs(quant: bool = False):
    # MLA caches never quantize (see attention.mla_cache_init)
    return {"ckv": ("layers", "batch", "kv_seq", "kv_lora"),
            "krope": ("layers", "batch", "kv_seq", None),
            "idx": ("layers",)}


def cache_specs(cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        inner = (_mla_cache_specs(cfg.kv_cache_quant)
                 if cfg.attention_type == "mla"
                 else _gqa_cache_specs(cfg.kv_cache_quant))
        return {"layers": inner}
    if cfg.family == "vlm":
        base = _gqa_cache_specs(cfg.kv_cache_quant)
        return {"layers": {"self": {
            k: ("layers", *v) for k, v in base.items()}}}
    if cfg.family == "ssm":
        return {"layers": {
            "tmix_x": ("layers", "batch", "embed"),
            "cmix_x": ("layers", "batch", "embed"),
            "wkv": ("layers", "batch", "heads", None, None),
        }}
    if cfg.family == "hybrid":
        # shared attention cache stays unquantized (see transformer.init_cache)
        shared = {"k": ("layers", "batch", "kv_seq", "kv_heads", None),
                  "v": ("layers", "batch", "kv_seq", "kv_heads", None)}
        return {
            "mamba_state": ("layers", "batch", "heads", None, None),
            "conv_tail": ("layers", "batch", None, "ffn"),
            "shared": shared,
            "idx": (),
        }
    if cfg.family == "audio":
        return {
            "layers": _gqa_cache_specs(cfg.kv_cache_quant),
            "cross": {"k": ("layers", "batch", "frames", "kv_heads", None),
                      "v": ("layers", "batch", "frames", "kv_heads", None)},
        }
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------


def _sds(tree_shapes, tree_specs, mesh, rules):
    """(shape-tree, logical-spec-tree) -> ShapeDtypeStructs with shardings.

    Maps over the SPEC tree first (is_leaf=tuple) so that scalar specs ``()``
    are treated as leaves, not empty containers.
    """

    def one(s, t):
        spec = shd.resolve_spec(s, rules, mesh, t.shape)
        return jax.ShapeDtypeStruct(
            t.shape, t.dtype, sharding=jax.sharding.NamedSharding(mesh, spec))

    return jax.tree.map(one, tree_specs, tree_shapes,
                        is_leaf=lambda s: isinstance(s, tuple))


def _extra_shape(cfg: ModelConfig, batch: int):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct((batch, cfg.vision_seq, cfg.d_model), dt)
    if cfg.family == "audio":
        return jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model), dt)
    return None


def params_specs_sds(cfg: ModelConfig, mesh, rules=None):
    rules = rules or shd.PARAM_RULES
    shapes = jax.eval_shape(
        lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))
    logical = T.param_specs(cfg)
    return _sds(shapes, logical, mesh, rules)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh, tcfg=None,
                rules=None):
    """Returns (fn, tuple_of_abstract_args, donate_argnums) for the cell."""
    rules = rules or act_rules_for(shape)
    params = params_specs_sds(cfg, mesh)
    b, s = shape.global_batch, shape.seq_len
    tok_spec = shd.resolve_spec(("batch", None), rules, mesh, (b, s))
    tok_sharding = jax.sharding.NamedSharding(mesh, tok_spec)
    extra = _extra_shape(cfg, b)
    if extra is not None:
        e_spec = shd.resolve_spec(("batch", None, None), rules, mesh,
                                  extra.shape)
        extra = jax.ShapeDtypeStruct(
            extra.shape, extra.dtype,
            sharding=jax.sharding.NamedSharding(mesh, e_spec))

    if shape.kind == "train":
        from repro.train.train_step import TrainConfig, make_train_step

        opt_shapes = jax.eval_shape(opt.init_state, params)
        opt_logical = opt.state_specs(T.param_specs(cfg))
        opt_sds = _sds(opt_shapes, opt_logical, mesh, shd.PARAM_RULES)
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32,
                                           sharding=tok_sharding),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32,
                                           sharding=tok_sharding),
        }
        if extra is not None:
            batch["extra"] = extra
        step = make_train_step(cfg, tcfg or TrainConfig())
        return step, (params, opt_sds, batch), (0, 1)

    if shape.kind == "prefill":
        tokens = jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=tok_sharding)

        def fn(p, t, e):
            logits, cache = T.prefill(p, cfg, t, e)
            return logits[:, -1, :], cache

        return fn, (params, tokens, extra), ()

    # decode: one token against a seq_len cache
    extra_len = 0
    if cfg.family == "audio":
        extra_len = cfg.encoder_seq
    cache_shapes = jax.eval_shape(
        lambda: T.init_cache(cfg, b, s, extra_len))
    cache_sds = _sds(cache_shapes, cache_specs(cfg), mesh, rules)
    tok1_spec = shd.resolve_spec(("batch", None), rules, mesh, (b, 1))
    token = jax.ShapeDtypeStruct(
        (b, 1), jnp.int32,
        sharding=jax.sharding.NamedSharding(mesh, tok1_spec))
    dec_extra = extra if cfg.family == "vlm" else None

    def fn(p, t, c, e):
        logits, new_cache = T.decode_step(p, cfg, t, c, e)
        return logits, new_cache

    return fn, (params, token, cache_sds, dec_extra), (2,)
