"""Analytic FLOPs / bytes per (arch x shape) — the idealized roofline bound.

Complements the compiled-HLO extraction (hlo_analysis.py): the HLO numbers
include CPU-backend artifacts (weak elementwise fusion materializes attention
logits; remat recompute), so every cell reports BOTH:
  * hlo_*      — pessimistic, from the compiled artifact,
  * analytic_* — idealized (perfectly fused attention kernel, params read
                 once, activations touched twice per op).

MODEL_FLOPS follows the assignment: 6·N·D dense, 6·N_active·D for MoE.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models import transformer as T


def param_counts(cfg: ModelConfig) -> dict:
    """Exact parameter counts from the abstract param tree (no allocation)."""
    shapes = jax.eval_shape(lambda k: T.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    total = 0
    routed = 0
    embed_like = 0
    leaves, _ = jax.tree_util.tree_flatten_with_path(shapes)
    for path, leaf in leaves:
        n = int(np.prod(leaf.shape))
        total += n
        keys = "/".join(str(getattr(p, "key", "")) for p in path)
        if "moe" in keys and keys.split("/")[-1] in ("gate", "up", "down"):
            routed += n
        if keys.split("/")[-1] in ("embed", "lm_head", "enc_pos", "dec_pos"):
            embed_like += n
    active = total
    if cfg.n_experts:
        active = total - routed + routed * (cfg.moe_top_k / cfg.n_experts)
    return {
        "total": total,
        "active": int(active),
        "routed": routed,
        "embed_like": embed_like,
        "matmul_total": total - embed_like,
        "matmul_active": int(active) - embed_like,
    }


def _bytes_of(cfg: ModelConfig) -> int:
    return 2 if cfg.dtype == "bfloat16" else 4


def _attn_flops(cfg: ModelConfig, seq: int, batch: int, causal_exact: bool,
                fwd_only: bool) -> float:
    """Score+PV einsum FLOPs.  causal_exact=True models the paper's mapped
    triangular grid (T(nb) blocks ~ half the box); False is the BB square."""
    if cfg.family == "ssm":
        # chunked WKV: per chunk C^2 interactions per head-dim
        c = 64
        h, hd = cfg.rwkv_heads, cfg.d_model // cfg.rwkv_heads
        per_tok = 2 * c * (2 * hd * hd + hd) / 1  # P build + PV + state
        return 3 * batch * seq * per_tok * h * cfg.n_layers
    if cfg.family == "hybrid":
        c = 64
        h, p, n = cfg.mamba_heads, cfg.mamba_d_inner // cfg.mamba_heads, cfg.ssm_state
        ssd = 2 * batch * seq * c * h * (p + n) * cfg.n_layers
        n_attn = cfg.n_layers // cfg.hybrid_attn_every
        attn = 4 * batch * seq * seq * cfg.n_heads * cfg.head_dim * n_attn
        if causal_exact:
            attn *= 0.5
        return ssd + (attn if not fwd_only else attn / 3)
    # attention transformers
    if cfg.family == "audio":
        enc = 4 * batch * cfg.encoder_seq ** 2 * cfg.n_heads * cfg.head_dim \
            * cfg.encoder_layers
        dec_self = 4 * batch * seq * seq * cfg.n_heads * cfg.head_dim \
            * cfg.decoder_layers
        x = 4 * batch * seq * cfg.encoder_seq * cfg.n_heads * cfg.head_dim \
            * cfg.decoder_layers
        if causal_exact:
            dec_self *= 0.5
        total = enc + dec_self + x
    elif cfg.family == "vlm":
        n_groups = cfg.n_layers // cfg.cross_attn_every
        n_self = cfg.n_layers - n_groups
        self_f = 4 * batch * seq * seq * cfg.n_heads * cfg.head_dim * n_self
        x = 4 * batch * seq * cfg.vision_seq * cfg.n_heads * cfg.head_dim \
            * n_groups
        if causal_exact:
            self_f *= 0.5
        total = self_f + x
    else:
        hd = (cfg.qk_nope_dim + cfg.qk_rope_dim + cfg.v_head_dim
              ) if cfg.attention_type == "mla" else 2 * cfg.head_dim
        total = 2 * batch * seq * seq * cfg.n_heads * hd * cfg.n_layers
        if causal_exact:
            total *= 0.5
    mult = 1.0 if fwd_only else 3.0
    return total * mult


def cell_analytics(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    pc = param_counts(cfg)
    bts = _bytes_of(cfg)
    b, s = shape.global_batch, shape.seq_len
    d_tokens = b * s
    out = {"params_total": pc["total"], "params_active": pc["active"]}

    if shape.kind == "train":
        model_flops = 6.0 * pc["matmul_active"] * d_tokens \
            + 6.0 * pc["embed_like"] / max(cfg.padded_vocab, 1) * 0  # embeds: gather
        # lm head matmul is real compute:
        model_flops += 6.0 * d_tokens * cfg.d_model * cfg.padded_vocab
        attn_bb = _attn_flops(cfg, s, b, causal_exact=False, fwd_only=False)
        attn_mapped = _attn_flops(cfg, s, b, causal_exact=True, fwd_only=False)
        out.update({
            "model_flops": model_flops,
            "attn_flops_bb": attn_bb,
            "attn_flops_mapped": attn_mapped,
            "analytic_flops": model_flops + attn_mapped,
            # idealized HBM bytes: params read fwd+bwd + grads written +
            # adam state rw (fp32 m,v) + activations ~2 passes/layer
            "analytic_bytes": (
                3 * pc["active"] * bts + pc["total"] * (4 + 16)
                + 4.0 * cfg.n_layers * d_tokens * cfg.d_model * bts),
        })
    elif shape.kind == "prefill":
        model_flops = 2.0 * pc["matmul_active"] * d_tokens \
            + 2.0 * d_tokens * cfg.d_model * cfg.padded_vocab
        attn_bb = _attn_flops(cfg, s, b, causal_exact=False, fwd_only=True)
        attn_mapped = _attn_flops(cfg, s, b, causal_exact=True, fwd_only=True)
        out.update({
            "model_flops": model_flops,
            "attn_flops_bb": attn_bb,
            "attn_flops_mapped": attn_mapped,
            "analytic_flops": model_flops + attn_mapped,
            "analytic_bytes": (
                pc["active"] * bts
                + 2.0 * cfg.n_layers * d_tokens * cfg.d_model * bts),
        })
    else:  # decode: one token, cache of length s
        model_flops = 2.0 * pc["matmul_active"] * b \
            + 2.0 * b * cfg.d_model * cfg.padded_vocab
        if cfg.family in ("ssm", "hybrid"):
            attn = 0.0
            cache_bytes = _state_bytes(cfg, b)
            if cfg.family == "hybrid":
                n_attn = cfg.n_layers // cfg.hybrid_attn_every
                attn = 4.0 * b * s * cfg.n_heads * cfg.head_dim * n_attn
                cache_bytes += 2.0 * b * s * cfg.n_kv_heads * cfg.head_dim \
                    * bts * n_attn
        elif cfg.attention_type == "mla":
            attn = 2.0 * b * s * cfg.n_heads * (
                cfg.qk_nope_dim + cfg.qk_rope_dim + cfg.v_head_dim)
            attn += 4.0 * b * s * cfg.kv_lora_rank * cfg.n_heads * 0  # upproj
            attn *= cfg.n_layers
            cache_bytes = b * s * (cfg.kv_lora_rank + cfg.qk_rope_dim) * bts \
                * cfg.n_layers
        else:
            layers = cfg.decoder_layers if cfg.family == "audio" else cfg.n_layers
            attn = 4.0 * b * s * cfg.n_heads * cfg.head_dim * layers
            cache_bytes = 2.0 * b * s * cfg.n_kv_heads * cfg.head_dim * bts \
                * layers
        out.update({
            "model_flops": model_flops,
            "attn_flops_bb": attn, "attn_flops_mapped": attn,
            "analytic_flops": model_flops + attn,
            "analytic_bytes": pc["active"] * bts + cache_bytes,
        })
    return out


def _state_bytes(cfg: ModelConfig, b: int) -> float:
    if cfg.family == "ssm":
        h = cfg.rwkv_heads
        hd = cfg.d_model // h
        return 4.0 * b * h * hd * hd * cfg.n_layers
    h = cfg.mamba_heads
    return 4.0 * b * h * (cfg.mamba_d_inner // h) * cfg.ssm_state * cfg.n_layers


# ---------------------------------------------------------------------------
# Registry/artifact-driven deployment analytics (block-space kernels, Sec. V.C)
# ---------------------------------------------------------------------------


def _resolve_deployment(spec, n_points: int, block: int):
    """(domain, logic, mapped estimate, bb estimate) for any map spec —
    a domain name, ``Domain``, registry ``MapEntry`` or validated
    ``MappingArtifact``.  The logic class resolves through the MapRegistry
    (a bare domain name means its ground-truth entry), so the numbers always
    reflect the tier that would actually deploy — no per-domain if-chains."""
    from repro.core import energy
    from repro.core.artifact import resolve_spec
    from repro.core.domains import get_domain
    from repro.core.registry import REGISTRY

    domain_name, logic = resolve_spec(spec)
    d = get_domain(domain_name)
    if logic is None:
        logic = REGISTRY.ground_truth(domain_name).logic
    mp = energy.estimate_mapped(d, logic, n_points, block)
    bb = energy.estimate_bounding_box(d, n_points, block)
    return d, logic, mp, bb


def _deployment_dict(domain_name: str, logic: str, n_points: int,
                     mp, bb) -> dict:
    return {
        "domain": domain_name, "logic": logic, "n_points": n_points,
        "mapped_time_ms": mp.time_ms, "mapped_energy_j": mp.energy_j,
        "mapped_blocks": mp.total_blocks,
        "bb_time_ms": bb.time_ms, "bb_energy_j": bb.energy_j,
        "bb_blocks": bb.total_blocks, "bb_wasted_blocks": bb.wasted_blocks,
        "bb_waste_fraction": bb.waste_fraction,
        "speedup": bb.time_ms / mp.time_ms if mp.time_ms > 0 else float("inf"),
        "energy_reduction": (bb.energy_j / mp.energy_j
                             if mp.energy_j > 0 else float("inf")),
    }


def map_deployment_analytics(spec, n_points: int = 500_000_000,
                             block: int = 256) -> dict:
    """Deployment economics of any map spec: mapped vs bounding-box block
    accounting (any dimensionality, incl. the m-simplex and embedded-fractal
    families) plus the calibrated A100 cost model."""
    d, logic, mp, bb = _resolve_deployment(spec, n_points, block)
    return _deployment_dict(d.name, logic, n_points, mp, bb)


def artifact_deployment_analytics(artifact, n_points: int = 500_000_000,
                                  block: int = 256) -> dict:
    """Deployment economics of a validated ``MappingArtifact``: the registry
    accounting of :func:`map_deployment_analytics` plus the amortization of
    the artifact's one-time inference energy."""
    from repro.core import energy

    d, logic, mp, bb = _resolve_deployment(artifact, n_points, block)
    am = energy.amortization(d, logic, artifact.inference_joules, n_points,
                             bb=bb, mapped=mp)
    out = _deployment_dict(d.name, logic, n_points, mp, bb)
    out.update({
        "model": artifact.model, "stage": artifact.stage,
        "complexity_class": artifact.complexity_class,
        "report_digest": artifact.report_digest,
        "speedup": am.speedup, "energy_reduction": am.energy_reduction,
        "inference_joules": artifact.inference_joules,
        "runs_to_break_even": am.runs_to_break_even,
    })
    return out
