"""Per-request distributed tracing — stdlib only.

The serving stack spans processes (forward hops, peer pulls) and threads
(offload pools, batcher workers), so "why was this derive slow?" cannot be
answered from any single counter.  This module gives every request a trace
ID carried in the ``X-Repro-Trace-Id`` header: the ingress node generates
(or adopts) one, every outgoing hop re-sends it, and each node records the
spans *it* executed into a bounded ring buffer served by ``GET
/v1/trace/<id>``.  A cross-node trace is therefore assembled client-side by
asking each node for its shard of the same ID — no collector process, no
wire format beyond the JSON the servers already speak.

Span records are flat JSON dicts::

    {"name": "store_peer", "start_unix": ..., "duration_ms": ..., **attrs}

Propagation uses two mechanisms, matched to the two concurrency shapes in
the stack:

* **contextvars** for request-scoped call stacks: the HTTP frontends
  activate ``(buffer, trace_id)`` at ingress and everything that runs on
  that logical flow — including asyncio-offloaded work wrapped with
  ``contextvars.copy_context().run`` — records via :func:`span`.
* **the backend ``meta`` dict** for shared worker threads: a batcher's
  drain loop serves many requests from one thread, so contextvars cannot
  attribute its work.  :func:`meta_context` snapshots the active trace into
  ``meta[META_KEY]`` (in-process only — the tuple is never serialized) and
  the worker calls :func:`record_for_meta` against it.

Everything here is a no-op (one contextvar read) when no trace is active,
which is what keeps the hot path's instrumentation overhead in the noise.
"""
from __future__ import annotations

import collections
import contextvars
import threading
import time
import uuid
from typing import Any

#: wire header carrying the trace ID across forward hops and peer pulls
TRACE_HEADER = "X-Repro-Trace-Id"

#: reserved key under which `meta_context()` snapshots the active trace into
#: a backend `meta` dict (in-process hand-off to shared worker threads; the
#: value is a live (TraceBuffer, trace_id) tuple and must never hit the wire)
META_KEY = "_trace"

#: per-flow active trace: (TraceBuffer, trace_id) or None
_current: contextvars.ContextVar[tuple["TraceBuffer", str] | None] = \
    contextvars.ContextVar("repro_trace", default=None)


def new_trace_id() -> str:
    """A fresh 32-hex-char trace ID."""
    return uuid.uuid4().hex


def valid_trace_id(trace_id: Any) -> bool:
    """Lenient wire validation: 8..64 hex chars.  Anything else is ignored
    at ingress (a fresh ID is generated instead), so a hostile header can
    never grow the ring buffer's key space unboundedly per request."""
    if not isinstance(trace_id, str) or not 8 <= len(trace_id) <= 64:
        return False
    return all(c in "0123456789abcdef" for c in trace_id)


class TraceBuffer:
    """Bounded ring of recent traces (per node).

    At most ``max_traces`` trace IDs are held; recording into a new ID when
    full evicts the oldest trace wholesale.  Each trace holds at most
    ``max_spans`` spans — further records bump ``dropped_spans`` instead of
    growing, so a pathological request can't eat the buffer either."""

    def __init__(self, max_traces: int = 512, max_spans: int = 64):
        self.max_traces = max_traces
        self.max_spans = max_spans
        self.dropped_traces = 0  # whole traces evicted by the ring
        self.dropped_spans = 0   # spans refused by a full trace
        self._traces: "collections.OrderedDict[str, dict]" = \
            collections.OrderedDict()
        self._mu = threading.Lock()

    def record(self, trace_id: str, span: dict) -> None:
        with self._mu:
            entry = self._traces.get(trace_id)
            if entry is None:
                entry = self._traces[trace_id] = {
                    "trace_id": trace_id,
                    "started_unix": span.get("start_unix", time.time()),
                    "spans": [],
                    "dropped_spans": 0,
                }
                while len(self._traces) > self.max_traces:
                    self._traces.popitem(last=False)
                    self.dropped_traces += 1
            if len(entry["spans"]) >= self.max_spans:
                entry["dropped_spans"] += 1
                self.dropped_spans += 1
                return
            entry["spans"].append(span)

    def get(self, trace_id: str) -> dict | None:
        """This node's shard of one trace (a JSON-ready copy), or None."""
        with self._mu:
            entry = self._traces.get(trace_id)
            if entry is None:
                return None
            return {**entry, "spans": list(entry["spans"]),
                    "span_count": len(entry["spans"])}

    def ids(self) -> list[str]:
        """Known trace IDs, most recent last."""
        with self._mu:
            return list(self._traces)

    def stats(self) -> dict:
        with self._mu:
            return {"traces": len(self._traces),
                    "max_traces": self.max_traces,
                    "max_spans": self.max_spans,
                    "dropped_traces": self.dropped_traces,
                    "dropped_spans": self.dropped_spans}


# ---------------------------------------------------------------------------
# Context propagation + span recording
# ---------------------------------------------------------------------------


def activate(buffer: TraceBuffer, trace_id: str) -> contextvars.Token:
    """Make ``trace_id`` the active trace on this logical flow."""
    return _current.set((buffer, trace_id))


def deactivate(token: contextvars.Token) -> None:
    _current.reset(token)


def current_trace_id() -> str | None:
    """The active trace ID (what outgoing hops put on the wire), or None."""
    ctx = _current.get()
    return ctx[1] if ctx is not None else None


def wire_headers() -> dict:
    """``{TRACE_HEADER: id}`` when a trace is active, else ``{}`` — merge
    into any outgoing fleet request so the remote node records under the
    same ID."""
    ctx = _current.get()
    return {TRACE_HEADER: ctx[1]} if ctx is not None else {}


class _NoopSpan:
    __slots__ = ()

    def __enter__(self) -> dict:
        return {}

    def __exit__(self, *exc) -> bool:
        return False


_NOOP = _NoopSpan()


class _LiveSpan:
    __slots__ = ("_buffer", "_trace_id", "_name", "_attrs", "_t0", "_wall")

    def __init__(self, buffer: TraceBuffer, trace_id: str, name: str,
                 attrs: dict):
        self._buffer = buffer
        self._trace_id = trace_id
        self._name = name
        self._attrs = attrs

    def __enter__(self) -> dict:
        self._t0 = time.monotonic()
        self._wall = time.time()
        return self._attrs  # caller may add attrs mid-span

    def __exit__(self, exc_type, exc, tb) -> bool:
        rec = {"name": self._name, "start_unix": self._wall,
               "duration_ms": (time.monotonic() - self._t0) * 1e3}
        if exc_type is not None:
            rec["error"] = exc_type.__name__
        rec.update(self._attrs)
        self._buffer.record(self._trace_id, rec)
        return False


def span(name: str, **attrs):
    """Context manager recording one span into the active trace (a shared
    no-op when none is active).  Yields the attrs dict, so callers can
    attach outcomes discovered mid-span::

        with span("store_peer") as s:
            rec = probe()
            s["hit"] = rec is not None
    """
    ctx = _current.get()
    if ctx is None:
        return _NOOP
    return _LiveSpan(ctx[0], ctx[1], name, attrs)


def meta_context() -> dict:
    """Snapshot of the active trace for a backend ``meta`` dict (``{}``
    when inactive) — lets shared worker threads attribute their work via
    :func:`record_for_meta`."""
    ctx = _current.get()
    return {META_KEY: ctx} if ctx is not None else {}


def record_for_meta(meta: dict, name: str, seconds: float, **attrs) -> None:
    """Record a just-finished span of ``seconds`` against the trace carried
    in ``meta`` (no-op when the request was untraced)."""
    ctx = meta.get(META_KEY)
    if ctx is None:
        return
    buffer, trace_id = ctx
    buffer.record(trace_id, {
        "name": name, "start_unix": time.time() - seconds,
        "duration_ms": seconds * 1e3, **attrs})
