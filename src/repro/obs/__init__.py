"""Observability plane: metrics registry + request tracing (stdlib only).

One :class:`Observability` instance per server frontend bundles the three
measurement surfaces this package provides:

* per-endpoint request stats (bounded histogram buckets, not samples),
  feeding the JSON ``/metrics`` payload's ``http`` section unchanged;
* Prometheus text exposition of the same numbers
  (``GET /metrics?format=prometheus``);
* per-request traces (``X-Repro-Trace-Id``) in a bounded ring buffer,
  served by ``GET /v1/trace/<id>`` and ``GET /v1/traces``.

``enabled=False`` turns request *tracing* off (no ID generation, no
contextvar activation, no span records) while metrics keep flowing — the
knob behind ``--no-observability`` and the instrumentation-overhead
benchmark.
"""
from __future__ import annotations

import time

from repro.obs.metrics import (  # noqa: F401
    DEFAULT_BUCKETS,
    Counter,
    EndpointStats,
    Gauge,
    Histogram,
    MetricsRegistry,
    flatten_payload,
    parse_prometheus,
)
from repro.obs.trace import (  # noqa: F401
    META_KEY,
    TRACE_HEADER,
    TraceBuffer,
    current_trace_id,
    meta_context,
    new_trace_id,
    record_for_meta,
    span,
    valid_trace_id,
    wire_headers,
)
from repro.obs import trace as trace_mod


class Observability:
    """One frontend's bundle of registry + endpoint stats + trace buffer."""

    def __init__(self, mode: str = "", node: str = "", enabled: bool = True,
                 max_traces: int = 512, max_spans: int = 64):
        self.mode = mode          # "threaded" | "async"
        self.node = node          # this server's URL (set post-bind)
        self.enabled = enabled    # tracing on/off; metrics always flow
        self.started_unix = time.time()
        self._t0 = time.monotonic()
        self.registry = MetricsRegistry()
        self.traces = TraceBuffer(max_traces=max_traces, max_spans=max_spans)
        self._endpoint_cache: dict[str, EndpointStats] = {}

    def uptime_seconds(self) -> float:
        return time.monotonic() - self._t0

    # -- per-endpoint stats ------------------------------------------------
    def endpoint(self, name: str) -> EndpointStats:
        stats = self._endpoint_cache.get(name)
        if stats is None:
            hist = self.registry.histogram(
                "repro_http_request_seconds",
                "per-endpoint request latency", endpoint=name)
            stats = self._endpoint_cache.setdefault(name,
                                                    EndpointStats(hist))
        return stats

    def observe(self, endpoint: str, seconds: float, ok: bool) -> None:
        self.endpoint(endpoint).record(seconds, ok)

    def http_dict(self) -> dict:
        """The /metrics ``http`` section (shape-compatible with PR 3)."""
        return {name: stats.as_dict()
                for name, stats in sorted(self._endpoint_cache.items())}

    def frontend_dict(self) -> dict:
        """The /metrics ``frontend`` section — identical key set on both
        frontends (the parity contract); mode distinguishes them."""
        return {
            "mode": self.mode,
            "node": self.node,
            "observability": self.enabled,
            "uptime_seconds": self.uptime_seconds(),
            "started_unix": self.started_unix,
            "traces": self.traces.stats(),
        }

    # -- request tracing ---------------------------------------------------
    def begin_request(self, header_value: str | None):
        """Activate a trace for one request: adopt a valid incoming ID or
        mint a fresh one.  Returns an opaque token for :meth:`end_request`
        (None when tracing is disabled)."""
        if not self.enabled:
            return None
        trace_id = header_value if valid_trace_id(header_value) \
            else new_trace_id()
        token = trace_mod.activate(self.traces, trace_id)
        return (token, trace_id)

    def end_request(self, token, endpoint: str, seconds: float,
                    ok: bool) -> None:
        """Record the request-level span and deactivate the trace."""
        if token is None:
            return
        cv_token, trace_id = token
        rec = {"name": endpoint, "start_unix": time.time() - seconds,
               "duration_ms": seconds * 1e3, "node": self.node}
        if not ok:
            rec["error"] = True
        self.traces.record(trace_id, rec)
        trace_mod.deactivate(cv_token)

    # -- wire payloads -----------------------------------------------------
    def trace_payload(self, trace_id: str) -> dict | None:
        entry = self.traces.get(trace_id)
        if entry is None:
            return None
        return {**entry, "node": self.node}

    def traces_payload(self) -> dict:
        ids = self.traces.ids()
        return {"node": self.node, "traces": ids, "count": len(ids),
                "stats": self.traces.stats()}

    def prometheus(self, payload: dict | None = None) -> str:
        return self.registry.prometheus(payload)
