"""Metrics registry: named counters, gauges, fixed-bucket histograms.

Replaces the ad-hoc dict/list counters the serving layer grew through PRs
3-7 with proper instruments:

* :class:`Counter` / :class:`Gauge` — named, labeled, thread-safe;
* :class:`Histogram` — **fixed-bucket** latency distribution.  Memory is
  bounded by construction (one int per bucket, forever), unlike the
  deque-of-samples the frontends used before; quantiles are estimated by
  linear interpolation inside the covering bucket, with the observed max
  bounding the overflow bucket;
* :class:`MetricsRegistry` — get-or-create by (name, labels), rendered as
  Prometheus text exposition (format 0.0.4: ``# HELP``/``# TYPE``,
  ``_bucket{le=...}``/``_sum``/``_count`` series).

The existing JSON ``/metrics`` payload stays the source of truth for its
nested shape (tests and the benchmark harness consume it); the Prometheus
view is generated from the same numbers — registered instruments first,
then every numeric leaf of the JSON payload flattened into
``repro_<path>`` gauges, so a scraper sees the whole surface without the
JSON consumers noticing anything changed.
"""
from __future__ import annotations

import math
import re
import threading
from typing import Any, Iterable

#: default latency buckets (seconds): 0.5ms hot-path hits .. 10s derivations
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

_NAME_OK = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*")
_SANITIZE = re.compile(r"[^a-zA-Z0-9_]")


def sanitize_name(raw: str) -> str:
    """A valid Prometheus metric-name fragment from an arbitrary key."""
    name = _SANITIZE.sub("_", str(raw))
    if not name or name[0].isdigit():
        name = "_" + name
    return name


def escape_label_value(value: Any) -> str:
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{sanitize_name(k)}="{escape_label_value(v)}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_value(value: float) -> str:
    if value != value:  # NaN
        return "NaN"
    if value in (math.inf, -math.inf):
        return "+Inf" if value > 0 else "-Inf"
    if isinstance(value, bool):
        return "1" if value else "0"
    if isinstance(value, int) or float(value).is_integer():
        return str(int(value))
    return repr(float(value))


class Counter:
    """Monotonically increasing named counter."""

    kind = "counter"

    def __init__(self, name: str, help: str = "", labels: dict | None = None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self._value = 0.0
        self._mu = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        with self._mu:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def samples(self) -> Iterable[tuple[str, dict, float]]:
        yield self.name, self.labels, self._value


class Gauge:
    """Point-in-time value (may go up or down)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "", labels: dict | None = None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self._value = 0.0
        self._mu = threading.Lock()

    def set(self, value: float) -> None:
        with self._mu:
            self._value = value

    def inc(self, amount: float = 1.0) -> None:
        with self._mu:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    @property
    def value(self) -> float:
        return self._value

    def samples(self) -> Iterable[tuple[str, dict, float]]:
        yield self.name, self.labels, self._value


class Histogram:
    """Fixed-bucket distribution (cumulative counts, Prometheus-style).

    ``observe`` is O(len(buckets)) with zero allocation; storage is one int
    per bucket regardless of how many samples a long-lived server sees —
    this is what bounds the frontends' per-endpoint latency memory.
    ``quantile`` interpolates linearly inside the covering bucket; the
    open-ended overflow bucket is capped at the observed maximum so a p99
    estimate can never exceed reality."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "", labels: dict | None = None,
                 buckets: tuple[float, ...] = DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self.buckets = tuple(sorted(buckets))
        self._counts = [0] * (len(self.buckets) + 1)  # +1: overflow (+Inf)
        self._sum = 0.0
        self._count = 0
        self._max = 0.0
        self._mu = threading.Lock()

    def observe(self, value: float) -> None:
        with self._mu:
            self._sum += value
            self._count += 1
            if value > self._max:
                self._max = value
            for i, bound in enumerate(self.buckets):
                if value <= bound:
                    self._counts[i] += 1
                    return
            self._counts[-1] += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def quantile(self, q: float) -> float:
        """Estimated q-quantile in the observed unit (0.0 when empty)."""
        with self._mu:
            total = self._count
            if total == 0:
                return 0.0
            target = q * total
            if target < 1.0:
                target = 1.0
            cumulative = 0
            lo = 0.0
            for i, bound in enumerate(self.buckets):
                in_bucket = self._counts[i]
                if cumulative + in_bucket >= target:
                    frac = (target - cumulative) / in_bucket
                    hi = min(bound, self._max) if self._max > lo else bound
                    return lo + (hi - lo) * frac
                cumulative += in_bucket
                lo = bound
            # overflow bucket: interpolate toward the observed max
            in_bucket = self._counts[-1]
            if in_bucket == 0:
                return lo
            frac = min(1.0, (target - cumulative) / in_bucket)
            return lo + (max(self._max, lo) - lo) * frac

    def samples(self) -> Iterable[tuple[str, dict, float]]:
        with self._mu:
            counts = list(self._counts)
            total, acc = self._count, self._sum
        cumulative = 0
        for bound, n in zip(self.buckets, counts):
            cumulative += n
            yield (self.name + "_bucket",
                   {**self.labels, "le": _fmt_value(bound)}, cumulative)
        yield self.name + "_bucket", {**self.labels, "le": "+Inf"}, total
        yield self.name + "_sum", self.labels, acc
        yield self.name + "_count", self.labels, total


class EndpointStats:
    """Per-endpoint request counters over a bounded histogram.

    Publishes the exact JSON dict shape the frontends have always served
    (``{requests, errors, p50_ms, p95_ms}``) so every existing /metrics
    consumer keeps working — but backed by fixed buckets instead of an
    unbounded (well, deque-bounded) latency sample."""

    def __init__(self, histogram: Histogram):
        self.histogram = histogram
        self.errors = 0
        self._mu = threading.Lock()

    def record(self, seconds: float, ok: bool) -> None:
        self.histogram.observe(seconds)
        if not ok:
            with self._mu:
                self.errors += 1

    def as_dict(self) -> dict:
        return {
            "requests": self.histogram.count,
            "errors": self.errors,
            "p50_ms": self.histogram.quantile(0.50) * 1e3,
            "p95_ms": self.histogram.quantile(0.95) * 1e3,
            "p99_ms": self.histogram.quantile(0.99) * 1e3,
        }


class MetricsRegistry:
    """Get-or-create instrument registry keyed by (name, sorted labels)."""

    def __init__(self):
        self._instruments: dict[tuple, Any] = {}
        self._mu = threading.Lock()

    def _get(self, cls, name: str, help: str, labels: dict, **kw):
        if not _NAME_OK.fullmatch(name):
            raise ValueError(f"invalid metric name {name!r}")
        key = (name, tuple(sorted(labels.items())))
        with self._mu:
            inst = self._instruments.get(key)
            if inst is None:
                inst = self._instruments[key] = cls(
                    name, help=help, labels=labels, **kw)
            elif not isinstance(inst, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {inst.kind}")
            return inst

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple[float, ...] = DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def instruments(self) -> list:
        with self._mu:
            return list(self._instruments.values())

    def prometheus(self, payload: dict | None = None,
                   payload_prefix: str = "repro") -> str:
        """Text exposition (format 0.0.4) of every registered instrument,
        plus — when given — each numeric leaf of a nested JSON ``payload``
        flattened to ``<payload_prefix>_<path>`` gauges."""
        lines: list[str] = []
        seen_meta: set[str] = set()
        for inst in self.instruments():
            if inst.name not in seen_meta:
                seen_meta.add(inst.name)
                if inst.help:
                    lines.append(f"# HELP {inst.name} {inst.help}")
                lines.append(f"# TYPE {inst.name} {inst.kind}")
            for name, labels, value in inst.samples():
                lines.append(
                    f"{name}{_fmt_labels(labels)} {_fmt_value(value)}")
        if payload is not None:
            for name, value in flatten_payload(payload, payload_prefix):
                if name not in seen_meta:
                    seen_meta.add(name)
                    lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name} {_fmt_value(value)}")
        return "\n".join(lines) + "\n"


def flatten_payload(payload: dict, prefix: str = "repro",
                    ) -> list[tuple[str, float]]:
    """Every numeric leaf of a nested dict as (metric_name, value), path
    components joined by ``_`` and sanitized — how the JSON /metrics shape
    becomes scrapeable without maintaining two bookkeeping systems."""
    out: list[tuple[str, float]] = []

    def walk(node: Any, path: str) -> None:
        if isinstance(node, bool):
            out.append((path, 1.0 if node else 0.0))
        elif isinstance(node, (int, float)):
            out.append((path, float(node)))
        elif isinstance(node, dict):
            for k, v in node.items():
                walk(v, f"{path}_{sanitize_name(k)}")
        # lists/strings/None are skipped: not time-series material

    walk(payload, sanitize_name(prefix))
    return out


def parse_prometheus(text: str) -> dict[str, float]:
    """Minimal exposition parser (tests + loadgen): ``{name{labels}: value}``.
    Raises ValueError on a malformed line, which is exactly what the
    format-validity tests want to detect."""
    out: dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        series, _, raw = line.rpartition(" ")
        if not series:
            raise ValueError(f"malformed exposition line: {line!r}")
        name = series.split("{", 1)[0]
        if not _NAME_OK.fullmatch(name):
            raise ValueError(f"invalid series name in line: {line!r}")
        try:
            value = float(raw)
        except ValueError as e:
            raise ValueError(f"non-numeric sample in line: {line!r}") from e
        out[series] = value
    return out
