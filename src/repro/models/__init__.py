"""Model substrate: layers, attention variants, MoE, SSMs, full architectures."""
