"""RWKV-6 "Finch" — attention-free time mixing with data-dependent decay.

Recurrence (per head, state S in R^{dk x dv}):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t^T S_{t-1} + (u ⊙ r_t)·k_t  v_t
with w_t = exp(-exp(decay_t)) data-dependent (LoRA on the shifted input).

Two equivalent evaluation paths:
  * ``rwkv_scan``    — the recurrence via lax.scan (oracle; O(T) sequential),
  * ``rwkv_chunked`` — chunkwise-parallel form (production): within a chunk
    of length C the contribution is a strictly-lower-triangular matmul over
    decay-rescaled r̃/k̃ (the *triangular block domain again* — the paper's
    2D map applies to the chunk-pair space), across chunks a scan over the
    per-chunk state update  S <- A_C ⊙ S + k̃_C^T V.

The paper's technique does not apply to RWKV attention (attention-free);
see DESIGN.md §Arch-applicability.  Decode is O(1)/token via the state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import EMBED, FFN, HEADS, dense_init, rms_norm


def rwkv_block_init(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.rwkv_heads
    hd = d // h
    ks = jax.random.split(key, 12)
    lora = cfg.rwkv_decay_lora
    return {
        # token-shift mix coefficients (static lerp per projection)
        "mix_r": jnp.full((d,), 0.5, dtype),
        "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_v": jnp.full((d,), 0.5, dtype),
        "mix_g": jnp.full((d,), 0.5, dtype),
        "mix_w": jnp.full((d,), 0.5, dtype),
        "wr": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "wg": dense_init(ks[3], d, d, dtype),
        # data-dependent decay LoRA: d -> lora -> d
        "wd1": dense_init(ks[4], d, lora, dtype),
        "wd2": dense_init(ks[5], lora, d, dtype, scale=0.01),
        "decay_base": jnp.full((d,), -6.0, jnp.float32),
        "bonus_u": jnp.full((h, hd), 0.5, jnp.float32),
        "wo": dense_init(ks[6], d, d, dtype),
        "ln_x": jnp.ones((d,), dtype),  # per-head group norm weight
    }


def rwkv_block_specs(cfg):
    return {
        "mix_r": (EMBED,), "mix_k": (EMBED,), "mix_v": (EMBED,),
        "mix_g": (EMBED,), "mix_w": (EMBED,),
        "wr": (EMBED, FFN), "wk": (EMBED, FFN), "wv": (EMBED, FFN),
        "wg": (EMBED, FFN),
        "wd1": (EMBED, None), "wd2": (None, FFN),
        "decay_base": (None,), "bonus_u": (HEADS, None),
        "wo": (FFN, EMBED), "ln_x": (EMBED,),
    }


def _token_shift(x, x_prev):
    """x_{t-1} with x_prev filling t=0; returns shifted tensor.

    x_prev state is carried fp32 (decode caches); cast to the compute dtype
    so bf16 models stay bf16 through the mix projections.
    """
    return jnp.concatenate(
        [x_prev[:, None, :].astype(x.dtype), x[:, :-1, :]], axis=1)


def _projections(p, cfg, x, x_prev):
    xs = _token_shift(x, x_prev)

    def mix(m):
        return x * m + xs * (1.0 - m)

    r = jnp.einsum("bsd,de->bse", mix(p["mix_r"]), p["wr"])
    k = jnp.einsum("bsd,de->bse", mix(p["mix_k"]), p["wk"])
    v = jnp.einsum("bsd,de->bse", mix(p["mix_v"]), p["wv"])
    g = jnp.einsum("bsd,de->bse", mix(p["mix_g"]), p["wg"])
    dec = p["decay_base"] + jnp.einsum(
        "bsl,ld->bsd",
        jnp.tanh(jnp.einsum("bsd,dl->bsl", mix(p["mix_w"]), p["wd1"])),
        p["wd2"],
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec))  # decay in (0, 1), fp32
    return r, k, v, g, w


def _split_heads(t, h):
    b, s, d = t.shape
    return t.reshape(b, s, h, d // h)


def rwkv_mix_chunked(p, cfg, x, x_prev, state, chunk: int = 64):
    """Chunkwise-parallel WKV.  x: (B,S,d); state: (B,h,dk,dv) carried in.

    Returns (out, last_x, new_state).
    """
    b, s, d = x.shape
    h = cfg.rwkv_heads
    hd = d // h
    r, k, v, g, w = _projections(p, cfg, x, x_prev)
    rh = _split_heads(r, h).astype(jnp.float32)
    kh = _split_heads(k, h).astype(jnp.float32)
    vh = _split_heads(v, h).astype(jnp.float32)
    wh = _split_heads(w, h)  # fp32 decays (B,S,h,hd)
    u = p["bonus_u"]          # (h, hd)

    nc = s // chunk
    assert s % chunk == 0, "sequence must be chunk-aligned"
    # (B, nc, C, h, hd) -> (nc, B, h, C, hd)
    def chunkify(t):
        return t.reshape(b, nc, chunk, h, hd).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, wc = map(chunkify, (rh, kh, vh, wh))

    logw = jnp.log(wc)                        # (nc,B,h,C,hd)
    clog = jnp.cumsum(logw, axis=3)           # A_t = prod_{u<=t} w_u
    a_end = jnp.exp(clog[:, :, :, -1:, :])    # A_C

    # r̃_t = r_t * A_{t-1} ; k̃_s = k_s / A_s  (A_0 = 1)
    a_prev = jnp.exp(jnp.concatenate(
        [jnp.zeros_like(clog[:, :, :, :1]), clog[:, :, :, :-1]], axis=3))
    r_t = rc * a_prev
    k_t = kc * jnp.exp(-clog)

    # intra-chunk: strictly-lower-triangular P + bonus diagonal
    pmat = jnp.einsum("nbhck,nbhdk->nbhcd", r_t, k_t)   # (nc,B,h,C,C)
    tril = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    pmat = jnp.where(tril, pmat, 0.0)
    diag = jnp.einsum("nbhck,nbhck->nbhc", rc * u[None, None, :, None, :], kc)
    o_intra = jnp.einsum("nbhcd,nbhdk->nbhck", pmat, vc) + diag[..., None] * vc

    # cross-chunk: scan the state;  o_cross_t = r̃_t^T S_in
    kt_v = jnp.einsum("nbhck,nbhcv->nbhkv", k_t, vc)    # sum_s k̃_s v_s^T

    def step(S, inputs):
        r_tc, a_e, kv = inputs
        o_cross = jnp.einsum("bhck,bhkv->bhcv", r_tc, S)
        # S_out = A_C ⊙ S_in + Σ_s (A_C/A_s) k_s v_s^T = A_C ⊙ (S_in + kv)
        a_vec = a_e[:, :, 0, :]                      # (B, h, hd_k)
        S_new = jnp.einsum("bhk,bhkv->bhkv", a_vec, S + kv)
        return S_new, o_cross

    state_f, o_cross = jax.lax.scan(step, state.astype(jnp.float32),
                                    (r_t, a_end, kt_v))
    o = (o_intra + o_cross).transpose(1, 0, 3, 2, 4).reshape(b, s, h, hd)

    # per-head group norm, then output gate
    o = rms_norm(o, jnp.ones((hd,), o.dtype)).reshape(b, s, d).astype(x.dtype)
    o = o * p["ln_x"]
    o = o * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", o, p["wo"])
    return out, x[:, -1, :].astype(jnp.float32), state_f.astype(state.dtype)


def rwkv_mix_scan(p, cfg, x, x_prev, state):
    """Oracle: the recurrence step-by-step via lax.scan."""
    b, s, d = x.shape
    h = cfg.rwkv_heads
    hd = d // h
    r, k, v, g, w = _projections(p, cfg, x, x_prev)
    rh = _split_heads(r, h).astype(jnp.float32)
    kh = _split_heads(k, h).astype(jnp.float32)
    vh = _split_heads(v, h).astype(jnp.float32)
    wh = _split_heads(w, h)
    u = p["bonus_u"]

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (B,h,hd)
        o_t = jnp.einsum("bhk,bhkv->bhv", r_t, S) + \
            jnp.einsum("bhk,bhk,bhv->bhv", r_t * u[None], k_t, v_t)
        S_new = w_t[..., None] * S + k_t[..., None] * v_t[..., None, :]
        return S_new, o_t

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (rh, kh, vh, wh))
    state_f, o = jax.lax.scan(step, state.astype(jnp.float32), xs)
    o = o.transpose(1, 0, 2, 3).reshape(b, s, h, hd)
    o = rms_norm(o, jnp.ones((hd,), o.dtype)).reshape(b, s, d).astype(x.dtype)
    o = o * p["ln_x"]
    o = o * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", o, p["wo"])
    return out, x[:, -1, :].astype(jnp.float32), state_f.astype(state.dtype)


# -- channel mix (RWKV FFN) --------------------------------------------------


def rwkv_cmix_init(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_r": jnp.full((d,), 0.5, dtype),
        "wk": dense_init(ks[0], d, f, dtype),
        "wv": dense_init(ks[1], f, d, dtype),
        "wr": dense_init(ks[2], d, d, dtype),
    }


def rwkv_cmix_specs(cfg):
    return {
        "mix_k": (EMBED,), "mix_r": (EMBED,),
        "wk": (EMBED, FFN), "wv": (FFN, EMBED), "wr": (EMBED, None),
    }


def rwkv_cmix_apply(p, cfg, x, x_prev):
    xs = _token_shift(x, x_prev)
    xk = x * p["mix_k"] + xs * (1.0 - p["mix_k"])
    xr = x * p["mix_r"] + xs * (1.0 - p["mix_r"])
    kk = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["wk"])))
    vv = jnp.einsum("bsf,fd->bsd", kk, p["wv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"]))
    return rr * vv, x[:, -1, :].astype(jnp.float32)
