"""Mixture-of-Experts layer with sort-based capacity dispatch (EP-shardable).

Dispatch keeps tensors at (E, C, d) — never (tokens, E, C) — so the layer
compiles at DeepSeek-V2 scale (160 experts, 1M tokens):

  1. router: softmax top-k over expert logits,
  2. flatten (token, k) assignments, sort by expert id,
  3. position-within-expert via sorted-segment ranks; drop beyond capacity C,
  4. scatter tokens into (E, C, d), run gated-SwiGLU experts batched over E,
  5. gather back with routing weights; dropped tokens fall through to the
     residual (plus shared experts, which always run densely).

With `experts -> model` sharding the scatter/gather become the all-to-alls
of expert parallelism under SPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

try:  # public since jax 0.5 (replication check kwarg renamed to check_vma)
    from jax import shard_map as _shard_map
    _SHARD_MAP_NOCHECK = {"check_vma": False}
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_NOCHECK = {"check_rep": False}

from repro.distribution.sharding import logical_constraint as lc
from repro.models.common import CAP, EMBED, EXPERTS, FFN, dense_init, mlp_init, mlp_specs


def moe_init(key, cfg, dtype):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),  # router math in fp32
        "gate": dense_init(ks[1], d, (e, f), dtype),
        "up": dense_init(ks[2], d, (e, f), dtype),
        "down": dense_init(ks[3], f, (e, d), dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, cfg.expert_d_ff * cfg.n_shared_experts,
                               dtype)
    return p


def moe_specs(cfg):
    s = {
        "router": (EMBED, EXPERTS),
        "gate": (EMBED, EXPERTS, FFN),
        "up": (EMBED, EXPERTS, FFN),
        "down": (FFN, EXPERTS, EMBED),
    }
    if cfg.n_shared_experts:
        s["shared"] = mlp_specs()
    return s


def capacity_for(n_tokens: int, cfg) -> int:
    c = int(n_tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.n_experts)
    return max(-(-c // 8) * 8, 8)  # pad to 8 for TPU sublanes


def moe_apply(p, cfg, x, with_aux: bool = False):
    """x: (B, S, d) -> (B, S, d)  [or (out, aux_loss) with with_aux].

    With cfg.moe_groups > 1 the dispatch is *grouped*: tokens are split into
    G groups aligned with the data axis and scattered group-locally.  Under
    SPMD a global scatter into the (E, C, d) buffer cannot be proven local
    and lowers to giant buffer all-reduces (~53 GB each at DeepSeek scale);
    the grouped form keeps every scatter within one data shard and the only
    EP communication left is the expert-output gather.
    """
    impl = getattr(cfg, "moe_impl", "global")
    if impl == "a2a":
        from repro.distribution.sharding import current_ctx

        ctx = current_ctx()
        if ctx is not None and "model" in ctx.mesh.shape \
                and ctx.mesh.shape["model"] > 1:
            ndev = 1
            for v in ctx.mesh.shape.values():
                ndev *= v
            msh = ctx.mesh.shape["model"]
            # decode steps have fewer tokens than devices — a2a inapplicable
            if (x.shape[0] * x.shape[1]) % ndev == 0 \
                    and cfg.n_experts % msh == 0:
                return moe_apply_a2a(p, cfg, x, ctx.mesh, with_aux)
    if getattr(cfg, "moe_groups", 1) > 1 or impl == "grouped":
        return _moe_apply_grouped(p, cfg, x, with_aux)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    tokens = x.reshape(b * s, d)
    n = b * s
    cap = capacity_for(n, cfg)

    # 1. router (fp32)
    logits = jnp.einsum("td,de->te", tokens.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                  # (n, k)
    if cfg.moe_renormalize:
        top_w = top_w / (top_w.sum(axis=-1, keepdims=True) + 1e-9)
    aux = jnp.zeros((), jnp.float32)
    if with_aux:  # Switch-style load-balancing loss
        frac = jnp.mean(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=(0, 1))
        aux = e * jnp.sum(frac * probs.mean(axis=0))

    # 2. flatten assignments and sort by expert
    flat_e = top_e.reshape(-1)                              # (n*k,)
    flat_t = jnp.repeat(jnp.arange(n), k)                   # token ids
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]

    # 3. position of each assignment within its expert group
    start = jnp.cumsum(
        jnp.bincount(se, length=e)
    ) - jnp.bincount(se, length=e)                          # group starts (e,)
    pos = jnp.arange(n * k) - start[se]                     # rank in group
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e * cap)         # drop -> sentinel

    # 4. scatter to (E*C, d), batched expert MLP
    buf = jnp.zeros((e * cap, d), tokens.dtype)
    buf = buf.at[slot].set(tokens[st], mode="drop")
    buf = buf.reshape(e, cap, d)
    buf = lc(buf, "experts", None, None)
    g = jax.nn.silu(jnp.einsum("ecd,def->ecf", buf, p["gate"]))
    u = jnp.einsum("ecd,def->ecf", buf, p["up"])
    out_e = jnp.einsum("ecf,fed->ecd", g * u, p["down"])    # (e, cap, d)
    out_e = lc(out_e, "experts", None, None)

    # 5. gather back with weights; dropped slots contribute zero
    flat_out = out_e.reshape(e * cap, d)
    safe_slot = jnp.minimum(slot, e * cap - 1)
    per_assign = jnp.where(
        keep[:, None], flat_out[safe_slot] * sw[:, None].astype(tokens.dtype),
        0.0,
    )
    out = jnp.zeros((n, d), tokens.dtype).at[st].add(per_assign)

    if cfg.n_shared_experts:
        from repro.models.common import swiglu

        out = out + swiglu(tokens, p["shared"]["gate"], p["shared"]["up"],
                           p["shared"]["down"])
    out = out.reshape(b, s, d)
    return (out, aux) if with_aux else out


def _moe_apply_grouped(p, cfg, x, with_aux: bool = False):
    """Group-local dispatch: (G, Tg, d) with G sharded on the data axis.

    All index math (top-k, stable sort, rank-in-expert, capacity drop,
    scatter, gather) is batched over G, so SPMD keeps it shard-local.
    The expert einsum slices E onto the model axis (free — the buffer's E
    dim is replicated per group) and the combine all-gathers expert outputs
    over the model axis — the single intrinsic EP collective.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    g = cfg.moe_groups
    n = b * s
    assert n % g == 0, "token count must split into moe_groups"
    tg = n // g
    cap = capacity_for(tg, cfg)
    toks = x.reshape(g, tg, d)
    toks = lc(toks, "experts_group", None, None)  # G -> data

    logits = jnp.einsum("gtd,de->gte", toks.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                    # (g, tg, k)
    if cfg.moe_renormalize:
        top_w = top_w / (top_w.sum(axis=-1, keepdims=True) + 1e-9)
    aux = jnp.zeros((), jnp.float32)
    if with_aux:
        frac = jnp.mean(jax.nn.one_hot(top_e, e, dtype=jnp.float32),
                        axis=(0, 1, 2))
        aux = e * jnp.sum(frac * probs.mean(axis=(0, 1)))

    flat_e = top_e.reshape(g, tg * k)
    flat_t = jnp.broadcast_to(jnp.arange(tg)[:, None], (tg, k)).reshape(-1)
    flat_w = top_w.reshape(g, tg * k)
    order = jnp.argsort(flat_e, axis=1, stable=True)          # (g, tg*k)
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st = flat_t[order]                                        # (g, tg*k)
    sw = jnp.take_along_axis(flat_w, order, axis=1)

    counts = jax.vmap(lambda ee: jnp.bincount(ee, length=e))(se)
    start = jnp.cumsum(counts, axis=1) - counts               # (g, e)
    pos = jnp.arange(tg * k)[None, :] - jnp.take_along_axis(start, se, axis=1)
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e * cap)

    gathered = jnp.take_along_axis(toks, st[..., None], axis=1)  # (g, tg*k, d)
    buf = jnp.zeros((g, e * cap, d), toks.dtype)
    buf = jax.vmap(lambda bb, sl, tk: bb.at[sl].set(tk, mode="drop"))(
        buf, slot, gathered)
    buf = buf.reshape(g, e, cap, d)
    buf = lc(buf, "experts_group", "experts", None, None)     # slice E->model

    gate = jax.nn.silu(jnp.einsum("gecd,def->gecf", buf, p["gate"]))
    up = jnp.einsum("gecd,def->gecf", buf, p["up"])
    out_e = jnp.einsum("gecf,fed->gecd", gate * up, p["down"])
    # the EP combine collective: expert outputs return to their groups
    out_e = lc(out_e, "experts_group", None, None, None)

    flat_out = out_e.reshape(g, e * cap, d)
    safe = jnp.minimum(slot, e * cap - 1)
    picked = jnp.take_along_axis(flat_out, safe[..., None], axis=1)
    per_assign = jnp.where(keep[..., None],
                           picked * sw[..., None].astype(toks.dtype), 0.0)
    out = jnp.zeros((g, tg, d), toks.dtype)
    out = jax.vmap(lambda oo, tt, pa: oo.at[tt].add(pa))(out, st, per_assign)

    if cfg.n_shared_experts:
        from repro.models.common import swiglu

        out = out + swiglu(toks, p["shared"]["gate"], p["shared"]["up"],
                           p["shared"]["down"])
    out = out.reshape(b, s, d)
    return (out, aux) if with_aux else out


# ---------------------------------------------------------------------------
# Explicit all-to-all expert parallelism (shard_map) — §Perf iteration
# ---------------------------------------------------------------------------


def _local_sort_dispatch(ids, n_buckets: int, cap: int):
    """Sort rows by bucket id; returns (slot, keep) with slot = b*cap+pos.

    Pure index math on one shard (no cross-device semantics)."""
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    counts = jnp.bincount(sorted_ids, length=n_buckets)
    start = jnp.cumsum(counts) - counts
    pos = jnp.arange(ids.shape[0]) - start[sorted_ids]
    keep = pos < cap
    slot_sorted = jnp.where(keep, sorted_ids * cap + pos, n_buckets * cap)
    # scatter back to original order
    slot = jnp.zeros_like(slot_sorted).at[order].set(slot_sorted)
    keep_orig = jnp.zeros_like(keep).at[order].set(keep)
    return slot, keep_orig


def moe_apply_a2a(p, cfg, x, mesh, with_aux: bool = False,
                  data_axes=("pod", "data"), model_axis="model"):
    """DeepSeek-style EP: token shards exchange with expert shards via two
    all-to-alls over the model axis (send: token->expert shard; return:
    expert outputs), instead of all-gathering every expert's outputs.

    Collective payload per device ≈ top_k · T_local · d (vs E·C·d for the
    gather-based combine) — the production EP schedule.
    """
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    axes_present = tuple(a for a in data_axes if a in mesh.shape)
    dsh = 1
    for a in axes_present:
        dsh *= mesh.shape[a]
    msh = mesh.shape[model_axis]
    n = b * s
    assert n % (dsh * msh) == 0 and e % msh == 0
    t_loc = n // (dsh * msh)
    e_loc = e // msh
    cap_send = max(-(-int(t_loc * k * cfg.capacity_factor) // msh) // 8 * 8, 8)
    cap_loc = max(-(-msh * cap_send // e_loc) // 8 * 8, 8)

    tokens = x.reshape(n, d)

    def device_fn(toks_shard, router_w, gate_w, up_w, down_w):
        # toks_shard: (n/dsh, d) — replicated over model; take my slice
        j = jax.lax.axis_index(model_axis)
        xt = jax.lax.dynamic_slice_in_dim(toks_shard, j * t_loc, t_loc, 0)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router_w)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, k)
        if cfg.moe_renormalize:
            top_w = top_w / (top_w.sum(-1, keepdims=True) + 1e-9)
        aux_l = jnp.zeros((), jnp.float32)
        if with_aux:
            frac = jnp.mean(jax.nn.one_hot(top_e, e, dtype=jnp.float32),
                            axis=(0, 1))
            aux_l = e * jnp.sum(frac * probs.mean(0))
            aux_l = jax.lax.pmean(aux_l, model_axis)
            for a in axes_present:
                aux_l = jax.lax.pmean(aux_l, a)

        eid = top_e.reshape(-1)                      # (t_loc*k,)
        tid = jnp.repeat(jnp.arange(t_loc), k)
        wgt = top_w.reshape(-1)
        dest = eid // e_loc
        slot, keep = _local_sort_dispatch(dest, msh, cap_send)
        sendx = jnp.zeros((msh * cap_send, d), xt.dtype)
        sendx = sendx.at[slot].set(xt[tid], mode="drop")
        send_eid = jnp.full((msh * cap_send,), 0, jnp.int32)
        send_eid = send_eid.at[slot].set((eid % e_loc).astype(jnp.int32),
                                         mode="drop")
        send_valid = jnp.zeros((msh * cap_send,), jnp.int32)
        send_valid = send_valid.at[slot].set(1, mode="drop")

        # ---- exchange tokens with expert shards --------------------------
        recvx = jax.lax.all_to_all(sendx.reshape(msh, cap_send, d),
                                   model_axis, 0, 0, tiled=False)
        recv_eid = jax.lax.all_to_all(send_eid.reshape(msh, cap_send),
                                      model_axis, 0, 0, tiled=False)
        recv_valid = jax.lax.all_to_all(send_valid.reshape(msh, cap_send),
                                        model_axis, 0, 0, tiled=False)
        rx = recvx.reshape(msh * cap_send, d)
        rid = recv_eid.reshape(-1)
        rvalid = recv_valid.reshape(-1).astype(bool)
        # invalid rows -> bucket e_loc (dropped by capacity sentinel)
        rid = jnp.where(rvalid, rid, e_loc)
        slot2, keep2 = _local_sort_dispatch(
            jnp.minimum(rid, e_loc), e_loc + 1, cap_loc)
        drop2 = (~keep2) | (rid >= e_loc)
        slot2 = jnp.where(drop2, (e_loc + 1) * cap_loc, slot2)
        buf = jnp.zeros(((e_loc + 1) * cap_loc, d), rx.dtype)
        buf = buf.at[slot2].set(rx, mode="drop")
        buf = buf[: e_loc * cap_loc].reshape(e_loc, cap_loc, d)

        # ---- local expert compute ----------------------------------------
        g_ = jax.nn.silu(jnp.einsum("ecd,def->ecf", buf, gate_w))
        u_ = jnp.einsum("ecd,def->ecf", buf, up_w)
        oute = jnp.einsum("ecf,fed->ecd", g_ * u_, down_w)

        # ---- return path --------------------------------------------------
        flat = oute.reshape(e_loc * cap_loc, d)
        safe2 = jnp.minimum(slot2, e_loc * cap_loc - 1)
        back = jnp.where(drop2[:, None], 0.0, flat[safe2])
        backx = jax.lax.all_to_all(back.reshape(msh, cap_send, d),
                                   model_axis, 0, 0, tiled=False)
        backx = backx.reshape(msh * cap_send, d)
        safe1 = jnp.minimum(slot, msh * cap_send - 1)
        per_assign = jnp.where(keep[:, None],
                               backx[safe1] * wgt[:, None].astype(xt.dtype),
                               0.0)
        out_loc = jnp.zeros((t_loc, d), xt.dtype).at[tid].add(per_assign)
        # reassemble the data shard's tokens across the model axis
        out_shard = jax.lax.all_gather(out_loc, model_axis, axis=0,
                                       tiled=True)
        return out_shard, aux_l

    tok_spec = P(axes_present if axes_present else None, None)
    w_e_spec = P(None, model_axis, None)
    down_spec = P(None, model_axis, None)
    out_fn = _shard_map(
        device_fn, mesh=mesh,
        in_specs=(tok_spec, P(None, None), w_e_spec, w_e_spec, down_spec),
        out_specs=(tok_spec, P()),
        **_SHARD_MAP_NOCHECK,
    )
    out, aux = out_fn(tokens, p["router"], p["gate"], p["up"], p["down"])
    out = out.reshape(b, s, d)
    if cfg.n_shared_experts:
        from repro.models.common import swiglu

        out = out + swiglu(x, p["shared"]["gate"], p["shared"]["up"],
                           p["shared"]["down"])
    return (out, aux) if with_aux else out
