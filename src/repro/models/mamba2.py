"""Mamba-2 (SSD) block — scalar-per-head decay state space, chunked.

    h_t = a_t h_{t-1} + dt_t · x_t ⊗ B_t        a_t = exp(dt_t · A_h) ∈ (0,1)
    y_t = C_t · h_t + D_h x_t

Chunkwise-parallel evaluation (production path) + lax.scan oracle.  The
intra-chunk term is again a lower-triangular (t, s) block domain — inclusive
diagonal this time.  Decode is O(1)/token on the (H, P, N) state plus a
width-(W-1) conv tail.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import EMBED, FFN, HEADS, dense_init, rms_norm


def mamba2_init(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.mamba_d_inner
    n = cfg.ssm_state
    h = cfg.mamba_heads
    w = cfg.mamba_conv_width
    ks = jax.random.split(key, 6)
    conv_dim = di + 2 * n
    return {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * n + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (w, conv_dim), jnp.float32)
                   * (1.0 / w)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def mamba2_specs(cfg):
    return {
        "in_proj": (EMBED, FFN),
        "conv_w": ("conv", FFN), "conv_b": (FFN,),
        "a_log": (HEADS,), "dt_bias": (HEADS,), "d_skip": (HEADS,),
        "norm": (FFN,),
        "out_proj": (FFN, EMBED),
    }


def _split_proj(cfg, zxbcdt):
    di, n, h = cfg.mamba_d_inner, cfg.ssm_state, cfg.mamba_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:]
    assert dt.shape[-1] == h
    return z, xbc, dt


def _causal_conv(xbc, w, b, tail=None):
    """Depthwise causal conv along seq; tail: (B, W-1, C) from previous call."""
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[-1]), xbc.dtype)
    xp = jnp.concatenate([tail, xbc], axis=1)
    out = sum(
        xp[:, i: i + xbc.shape[1], :] * w[i][None, None, :]
        for i in range(width)
    )
    new_tail = xp[:, -(width - 1):, :] if width > 1 else tail
    return jax.nn.silu(out + b), new_tail


def _ssm_inputs(p, cfg, xbc_act, dt_raw):
    di, n, h = cfg.mamba_d_inner, cfg.ssm_state, cfg.mamba_heads
    ph = di // h
    xh = xbc_act[..., :di]
    bmat = xbc_act[..., di:di + n].astype(jnp.float32)
    cmat = xbc_act[..., di + n:].astype(jnp.float32)
    bsz, s = xh.shape[:2]
    xh = xh.reshape(bsz, s, h, ph).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])                      # negative per-head A
    loga = dt * a[None, None, :]                  # log decay (B,S,H) < 0
    return xh, bmat, cmat, dt, loga


def mamba2_core_chunked(p, cfg, xbc_act, dt_raw, state, chunk: int = 64):
    """Chunked SSD. state: (B, H, P, N) fp32. Returns (y, new_state)."""
    xh, bmat, cmat, dt, loga = _ssm_inputs(p, cfg, xbc_act, dt_raw)
    bsz, s, h, ph = xh.shape
    n = bmat.shape[-1]
    nc = s // chunk
    assert s % chunk == 0

    def ck(t, last):
        return t.reshape((bsz, nc, chunk) + last).transpose(
            (1, 0) + tuple(range(2, t.ndim + 1)))

    xc = xh.reshape(bsz, nc, chunk, h, ph).transpose(1, 0, 3, 2, 4)   # nc,B,h,C,P
    bc = bmat.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)        # nc,B,C,N
    cc = cmat.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    dtc = dt.reshape(bsz, nc, chunk, h).transpose(1, 0, 3, 2)         # nc,B,h,C
    lac = loga.reshape(bsz, nc, chunk, h).transpose(1, 0, 3, 2)       # nc,B,h,C

    ca = jnp.cumsum(lac, axis=-1)                  # (nc,B,h,C)
    a_end = jnp.exp(ca[..., -1:])                  # (nc,B,h,1)

    # intra-chunk: P[t,s] = exp(ca_t - ca_s) (C_t·B_s) dt_s, s <= t
    rel = ca[..., :, None] - ca[..., None, :]      # (nc,B,h,C,C)
    tril = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: upper-triangle rel > 0 overflows to inf, and inf*0
    # in the backward of where() poisons gradients with NaN.
    gamma = jnp.exp(jnp.where(tril, rel, -1e30))
    cb = jnp.einsum("nbtN,nbsN->nbts", cc, bc)     # (nc,B,C,C)
    pm = gamma * cb[:, :, None, :, :] * dtc[..., None, :]
    y_intra = jnp.einsum("nbhts,nbhsp->nbhtp", pm, xc)

    # cross-chunk state scan
    # contribution into state: sum_s exp(ca_C - ca_s) dt_s x_s B_s^T
    w_in = jnp.exp(ca[..., -1:] - ca) * dtc        # (nc,B,h,C)
    dstate = jnp.einsum("nbhc,nbhcp,nbcN->nbhpN", w_in, xc, bc)

    def step(hst, inp):
        a_e, dst, c_t, ca_t = inp
        # y_cross_t = exp(ca_t) C_t · h_in
        y_cross = jnp.einsum("bhc,bhpN,bcN->bhcp", jnp.exp(ca_t), hst, c_t)
        h_new = a_e[..., None] * hst + dst
        return h_new, y_cross

    state_f, y_cross = jax.lax.scan(
        step, state.astype(jnp.float32), (a_end, dstate, cc, ca))
    y = y_intra + y_cross                          # (nc,B,h,C,P)
    y = y.transpose(1, 0, 3, 2, 4).reshape(bsz, s, h, ph)
    y = y + p["d_skip"][None, None, :, None] * xh  # skip connection
    return y, state_f


def mamba2_core_scan(p, cfg, xbc_act, dt_raw, state):
    """Oracle: step-by-step recurrence."""
    xh, bmat, cmat, dt, loga = _ssm_inputs(p, cfg, xbc_act, dt_raw)
    bsz, s, h, ph = xh.shape

    def step(hst, inp):
        x_t, b_t, c_t, dt_t, la_t = inp
        hst = jnp.exp(la_t)[..., None, None] * hst + \
            dt_t[..., None, None] * x_t[..., :, None] * b_t[:, None, None, :]
        y_t = jnp.einsum("bhpN,bN->bhp", hst, c_t)
        return hst, y_t

    xs = (xh.transpose(1, 0, 2, 3), bmat.transpose(1, 0, 2),
          cmat.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          loga.transpose(1, 0, 2))
    state_f, y = jax.lax.scan(step, state.astype(jnp.float32), xs)
    y = y.transpose(1, 0, 2, 3)
    y = y + p["d_skip"][None, None, :, None] * xh
    return y, state_f


def mamba2_apply(p, cfg, x, state=None, conv_tail=None, use_scan=False,
                 chunk: int = 64):
    """Full block. x: (B,S,d). Returns (out, new_state, new_conv_tail)."""
    bsz, s, _ = x.shape
    di, n, h = cfg.mamba_d_inner, cfg.ssm_state, cfg.mamba_heads
    if state is None:
        state = jnp.zeros((bsz, h, di // h, n), jnp.float32)
    zxbcdt = jnp.einsum("bsd,df->bsf", x, p["in_proj"])
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc_act, new_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_tail)
    use_scan = use_scan or (s % chunk != 0)  # decode / unaligned fallback
    core = mamba2_core_scan if use_scan else mamba2_core_chunked
    if use_scan:
        y, state_f = core(p, cfg, xbc_act, dt_raw, state)
    else:
        y, state_f = core(p, cfg, xbc_act, dt_raw, state, chunk)
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])    # gated norm
    out = jnp.einsum("bsf,fd->bsd", y, p["out_proj"])
    return out, state_f, new_tail
