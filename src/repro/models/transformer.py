"""Composable model assembly for all 10 assigned architectures.

Families:
  dense / moe — decoder-only transformer (GQA or MLA attention, SwiGLU MLP or
                sort-dispatch MoE), scan-over-layers.
  vlm         — decoder with one gated cross-attention (image) layer per
                ``cross_attn_every``-layer group; patch embeddings are a stub
                input (precomputed, already projected to d_model).
  ssm         — RWKV6 stack (time mix + channel mix), chunked WKV.
  hybrid      — Zamba2: Mamba2 backbone + ONE shared attention/MLP block
                invoked every ``hybrid_attn_every`` layers (weights shared,
                per-invocation KV caches).
  audio       — Whisper-style encoder-decoder; conv frontend is a stub input
                (precomputed frame embeddings).

Every family provides: init_params / param_specs / forward (teacher-forced
logits) / init_cache / prefill / decode_step.  All stacks scan over layers
with stacked params (compile-time + HBM win) and optional remat.

Note: ``jax.lax.scan`` treats ``None`` as an empty pytree, which lets the
cache-less (training) and cached (serving) paths share one scan body.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distribution.sharding import logical_constraint as lc
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv
from repro.models.common import (
    EMBED, VOCAB, dense_init, embed_init, layer_norm, mlp_init, mlp_specs,
    prepend_layers_axis, rms_norm, stack_layers, swiglu,
)

# ---------------------------------------------------------------------------
# shared small pieces
# ---------------------------------------------------------------------------


def _pol(cfg) -> str:
    """Effective remat policy string for a config."""
    return cfg.remat_policy if cfg.remat else "none"


def _remat(fn, enabled):
    """enabled: bool (legacy) or a ModelConfig-style policy string."""
    policy = enabled if isinstance(enabled, str) else ("full" if enabled else "none")
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": save only layer boundaries


def _gelu_mlp_init(key, d, f, dtype):
    k1, k2 = jax.random.split(key)
    return {"up": dense_init(k1, d, f, dtype), "down": dense_init(k2, f, d, dtype)}


def _gelu_mlp_specs():
    return {"up": (EMBED, "ffn"), "down": ("ffn", EMBED)}


def _gelu_mlp(p, x):
    return jnp.einsum("bsf,fd->bsd", jax.nn.gelu(
        jnp.einsum("bsd,df->bsf", x, p["up"])), p["down"])


def _attn_init(key, cfg, dtype):
    if cfg.attention_type == "mla":
        return attn.mla_init(key, cfg, dtype)
    return attn.gqa_init(key, cfg, dtype)


def _attn_specs(cfg):
    return attn.mla_specs(cfg) if cfg.attention_type == "mla" else attn.gqa_specs(cfg)


def _attn_apply(p, cfg, x, **kw):
    if cfg.attention_type == "mla":
        return attn.mla_apply(p, cfg, x, **kw)
    return attn.gqa_apply(p, cfg, x, **kw)


def _attn_cache_init(cfg, batch, max_seq, dtype):
    if cfg.attention_type == "mla":
        return attn.mla_cache_init(cfg, batch, max_seq, dtype)
    return attn.gqa_cache_init(cfg, batch, max_seq, dtype)


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _stack_cache(one, n: int):
    return jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (n, *t.shape)).copy(), one)


def _logits(params, cfg, h):
    h = rms_norm(h, params["final_norm"])
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, w,
                        preferred_element_type=jnp.float32)
    return lc(logits, "batch", None, "vocab")


def _embed(params, cfg, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    return lc(x, "batch", None, None)


def _bidir_attn(lp_attn, cfg, x):
    """Bidirectional self-attention (whisper encoder — box domain)."""
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhe->bshe", x, lp_attn["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, lp_attn["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, lp_attn["wv"])
    o = attn._sdpa(q, k, v, cfg.n_kv_heads, q_pos=None)
    return jnp.einsum(
        "bshe,hed->bsd", o,
        lp_attn["wo"].reshape(cfg.n_heads, cfg.head_dim, cfg.d_model))


# ===========================================================================
# dense / moe / vlm decoder layers
# ===========================================================================


def _layer_init(key, cfg, dtype, cross: bool = False):
    k1, k2 = jax.random.split(key)
    p: dict[str, Any] = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.gqa_init(k1, cfg, dtype) if cross
        else _attn_init(k1, cfg, dtype),
    }
    if cfg.family == "moe" and not cross:
        p["moe"] = moe_mod.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    if cross:
        p["xattn_gate"] = jnp.zeros((), jnp.float32)
    return p


def _layer_specs(cfg, cross: bool = False):
    s: dict[str, Any] = {
        "ln1": (EMBED,), "ln2": (EMBED,),
        "attn": attn.gqa_specs(cfg) if cross else _attn_specs(cfg),
    }
    if cfg.family == "moe" and not cross:
        s["moe"] = moe_mod.moe_specs(cfg)
    else:
        s["mlp"] = mlp_specs()
    if cross:
        s["xattn_gate"] = ()
    return s


def _layer_apply(p, cfg, x, *, positions=None, cache=None, cross_kv=None,
                 with_aux: bool = False):
    cross = cross_kv is not None
    apply = attn.gqa_apply if cross else _attn_apply
    h, new_cache = apply(
        p["attn"], cfg, rms_norm(x, p["ln1"]), positions=positions,
        cache=cache, cross_kv=cross_kv,
    )
    if cross:
        h = h * jnp.tanh(p["xattn_gate"]).astype(h.dtype)
    x = x + h
    inner = rms_norm(x, p["ln2"])
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        out = moe_mod.moe_apply(p["moe"], cfg, inner, with_aux=with_aux)
        if with_aux:
            out, aux = out
        x = x + out
    else:
        x = x + swiglu(inner, p["mlp"]["gate"], p["mlp"]["up"], p["mlp"]["down"])
    return (x, new_cache, aux) if with_aux else (x, new_cache)


def _decoder_stack(params, cfg, x, *, positions=None, caches=None,
                   cross_states=None, with_aux: bool = False):
    """Scan over layers; caches is a stacked pytree or None (both work).

    with_aux additionally returns the summed MoE load-balancing loss.
    """
    body = _remat(
        lambda xx, lp, c: _layer_apply(lp, cfg, xx, positions=positions,
                                       cache=c, with_aux=with_aux),
        _pol(cfg),
    )

    if cfg.family != "vlm":
        def f(xx, lp_c):
            lp, c = lp_c
            out = body(xx, lp, c)
            if with_aux:
                return out[0], (out[1], out[2])
            return out
        x, ys = jax.lax.scan(f, x, (params["layers"], caches))
        if with_aux:
            return x, ys[0], jnp.sum(ys[1])
        return x, ys

    # vlm: groups of (cross_attn_every - 1) self layers + 1 cross layer
    cross_body = _remat(
        lambda xx, lp, c: _layer_apply(lp, cfg, xx, positions=positions,
                                       cache=c, cross_kv=cross_states),
        _pol(cfg),
    )

    def group_fn(xx, gp_gc):
        gp, gc = gp_gc
        self_caches = None if gc is None else gc["self"]

        def self_fn(x_in, lp_c):
            lp, c = lp_c
            return body(x_in, lp, c)

        xx, new_self = jax.lax.scan(self_fn, xx, (gp["self"], self_caches))
        xx, _ = cross_body(xx, gp["cross"], None)
        return xx, (None if gc is None else {"self": new_self})

    return jax.lax.scan(group_fn, x, (params["groups"], caches))


# ===========================================================================
# RWKV6 (ssm)
# ===========================================================================


def _rwkv_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "tmix": rwkv.rwkv_block_init(k1, cfg, dtype),
        "cmix": rwkv.rwkv_cmix_init(k2, cfg, dtype),
    }


def _rwkv_layer_specs(cfg):
    return {
        "ln1": (EMBED,), "ln2": (EMBED,),
        "tmix": rwkv.rwkv_block_specs(cfg),
        "cmix": rwkv.rwkv_cmix_specs(cfg),
    }


def _rwkv_layer_apply(p, cfg, x, state):
    """state: dict(tmix_x, cmix_x, wkv). Chunked when seq allows, else scan."""
    use_scan = (x.shape[1] % 64 != 0)
    n1 = rms_norm(x, p["ln1"])
    if use_scan:
        o, last_x, wkv = rwkv.rwkv_mix_scan(p["tmix"], cfg, n1,
                                            state["tmix_x"], state["wkv"])
    else:
        o, last_x, wkv = rwkv.rwkv_mix_chunked(p["tmix"], cfg, n1,
                                               state["tmix_x"], state["wkv"])
    x = x + o
    n2 = rms_norm(x, p["ln2"])
    o2, last_c = rwkv.rwkv_cmix_apply(p["cmix"], cfg, n2, state["cmix_x"])
    x = x + o2
    return x, {"tmix_x": last_x, "cmix_x": last_c, "wkv": wkv}


def _rwkv_zero_state(cfg, batch):
    h = cfg.rwkv_heads
    hd = cfg.d_model // h
    return {
        "tmix_x": jnp.zeros((batch, cfg.d_model), jnp.float32),
        "cmix_x": jnp.zeros((batch, cfg.d_model), jnp.float32),
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
    }


def _rwkv_stack(params, cfg, x, caches=None):
    zero = None if caches is not None else _rwkv_zero_state(cfg, x.shape[0])
    body = _remat(
        lambda xx, lp, st: _rwkv_layer_apply(lp, cfg, xx, st), _pol(cfg))

    def f(xx, lp_c):
        lp, c = lp_c
        out, ns = body(xx, lp, c if c is not None else zero)
        return out, (ns if c is not None else None)

    return jax.lax.scan(f, x, (params["layers"], caches))


# ===========================================================================
# Zamba2 hybrid
# ===========================================================================


def _zamba_shared_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.gqa_init(k1, cfg, dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _zamba_shared_specs(cfg):
    return {
        "ln1": (EMBED,), "ln2": (EMBED,),
        "attn": attn.gqa_specs(cfg),
        "mlp": mlp_specs(),
    }


def _zamba_shared_apply(p, cfg, x, positions=None, cache=None):
    h, nc = attn.gqa_apply(p["attn"], cfg, rms_norm(x, p["ln1"]),
                           positions=positions, cache=cache)
    x = x + h
    x = x + swiglu(rms_norm(x, p["ln2"]), p["mlp"]["gate"], p["mlp"]["up"],
                   p["mlp"]["down"])
    return x, nc


def _hybrid_stack(params, cfg, x, *, positions=None, cache=None):
    """Scan over mamba layers; fire the shared attn block every `period`.

    cache: dict(mamba_state(L,...), conv_tail(L,...), shared{k,v}(n_inv,...),
    idx) or None.  Returns (x, new_cache_or_None).
    """
    period = cfg.hybrid_attn_every
    shared = params["shared"]
    bsz = x.shape[0]
    h, ph, n = cfg.mamba_heads, cfg.mamba_d_inner // cfg.mamba_heads, cfg.ssm_state
    idx = None if cache is None else cache["idx"]

    mamba_body = _remat(
        lambda xx, lp, st, tl: m2.mamba2_apply(
            lp["mamba"], cfg, rms_norm(xx, lp["ln"]), state=st, conv_tail=tl),
        _pol(cfg))
    shared_plain = _remat(
        lambda xx: _zamba_shared_apply(shared, cfg, xx, positions=positions)[0],
        _pol(cfg))

    def f(carry, inp):
        xx, shared_kv = carry
        lp, lidx, mstate, ctail = inp
        if mstate is None:
            mstate = jnp.zeros((bsz, h, ph, n), jnp.float32)
        hh, new_state, new_tail = mamba_body(xx, lp, mstate, ctail)
        xx = xx + hh
        fire = (lidx % period) == (period - 1)
        if shared_kv is None:  # training: no cache
            xx = jax.lax.cond(fire, shared_plain, lambda a: a, xx)
            return (xx, None), (None, None)
        inv = lidx // period

        def fire_fn(args):
            xx_, kv = args
            c = {"k": kv["k"][inv], "v": kv["v"][inv], "idx": idx}
            out, nc = _zamba_shared_apply(shared, cfg, xx_,
                                          positions=positions, cache=c)
            kv = {"k": kv["k"].at[inv].set(nc["k"]),
                  "v": kv["v"].at[inv].set(nc["v"])}
            return out, kv

        xx, shared_kv = jax.lax.cond(fire, fire_fn, lambda a: a,
                                     (xx, shared_kv))
        return (xx, shared_kv), (new_state, new_tail)

    if cache is None:
        (x, _), _ = jax.lax.scan(
            f, (x, None),
            (params["layers"], jnp.arange(cfg.n_layers), None, None))
        return x, None

    (x, new_shared), (new_states, new_tails) = jax.lax.scan(
        f, (x, {"k": cache["shared"]["k"], "v": cache["shared"]["v"]}),
        (params["layers"], jnp.arange(cfg.n_layers), cache["mamba_state"],
         cache["conv_tail"]))
    new_cache = {
        "mamba_state": new_states,
        "conv_tail": new_tails,
        "shared": new_shared,
        "idx": idx + x.shape[1],
    }
    return x, new_cache


# ===========================================================================
# Whisper (audio)
# ===========================================================================


def _whisper_enc_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1_w": jnp.ones((cfg.d_model,), dtype),
        "ln1_b": jnp.zeros((cfg.d_model,), dtype),
        "ln2_w": jnp.ones((cfg.d_model,), dtype),
        "ln2_b": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn.gqa_init(k1, cfg, dtype),
        "mlp": _gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _whisper_enc_layer_specs(cfg):
    return {
        "ln1_w": (EMBED,), "ln1_b": (EMBED,),
        "ln2_w": (EMBED,), "ln2_b": (EMBED,),
        "attn": attn.gqa_specs(cfg),
        "mlp": _gelu_mlp_specs(),
    }


def _whisper_dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1_w": jnp.ones((cfg.d_model,), dtype),
        "ln1_b": jnp.zeros((cfg.d_model,), dtype),
        "lnx_w": jnp.ones((cfg.d_model,), dtype),
        "lnx_b": jnp.zeros((cfg.d_model,), dtype),
        "ln2_w": jnp.ones((cfg.d_model,), dtype),
        "ln2_b": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn.gqa_init(k1, cfg, dtype),
        "xattn": attn.gqa_init(k2, cfg, dtype),
        "mlp": _gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def _whisper_dec_layer_specs(cfg):
    return {
        "ln1_w": (EMBED,), "ln1_b": (EMBED,),
        "lnx_w": (EMBED,), "lnx_b": (EMBED,),
        "ln2_w": (EMBED,), "ln2_b": (EMBED,),
        "attn": attn.gqa_specs(cfg),
        "xattn": attn.gqa_specs(cfg),
        "mlp": _gelu_mlp_specs(),
    }


def _whisper_encode(params, cfg, frames):
    """frames: (B, T_enc, d) stub embeddings -> encoder states."""
    x = frames + params["enc_pos"][None, : frames.shape[1], :]

    def enc_layer(xx, lp):
        n1 = layer_norm(xx, lp["ln1_w"], lp["ln1_b"])
        xx = xx + _bidir_attn(lp["attn"], cfg, n1)
        n2 = layer_norm(xx, lp["ln2_w"], lp["ln2_b"])
        xx = xx + _gelu_mlp(lp["mlp"], n2)
        return xx, None

    enc_layer = _remat(enc_layer, _pol(cfg))
    x, _ = jax.lax.scan(enc_layer, x, params["enc_layers"])
    return layer_norm(x, params["enc_norm_w"], params["enc_norm_b"])


def _whisper_dec_stack(params, cfg, x, enc_states, *, positions=None,
                       caches=None, cross_cache=None):
    """enc_states for full fwd/prefill; cross_cache {k,v}(L,...) for decode."""

    def dec_layer(xx, lp, c, xk, xv):
        h, nc = attn.gqa_apply(
            lp["attn"], cfg, layer_norm(xx, lp["ln1_w"], lp["ln1_b"]),
            positions=positions, cache=c)
        xx = xx + h
        nx = layer_norm(xx, lp["lnx_w"], lp["lnx_b"])
        if xk is not None:  # decode: cached per-layer cross k/v
            q = jnp.einsum("bsd,dhe->bshe", nx, lp["xattn"]["wq"])
            o = attn._sdpa(q, xk, xv, cfg.n_kv_heads, q_pos=None)
            xx = xx + jnp.einsum(
                "bshe,hed->bsd", o,
                lp["xattn"]["wo"].reshape(cfg.n_heads, cfg.head_dim,
                                          cfg.d_model))
        else:
            h2, _ = attn.gqa_apply(lp["xattn"], cfg, nx, cross_kv=enc_states)
            xx = xx + h2
        n2 = layer_norm(xx, lp["ln2_w"], lp["ln2_b"])
        xx = xx + _gelu_mlp(lp["mlp"], n2)
        return xx, nc

    dec_layer = _remat(dec_layer, _pol(cfg))

    def f(xx, inp):
        lp, c, xk, xv = inp
        return dec_layer(xx, lp, c, xk, xv)

    xk = None if cross_cache is None else cross_cache["k"]
    xv = None if cross_cache is None else cross_cache["v"]
    return jax.lax.scan(f, x, (params["dec_layers"], caches, xk, xv))


def _whisper_logits(params, cfg, x):
    h = layer_norm(x, params["final_norm"], params["final_norm_b"])
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return lc(logits, "batch", None, "vocab")


# ===========================================================================
# Public API
# ===========================================================================


def init_params(key, cfg):
    dtype = _dtype(cfg)
    if cfg.family in ("dense", "moe", "vlm"):
        k1, k2, k3 = jax.random.split(key, 3)
        params: dict[str, Any] = {
            "embed": embed_init(k1, cfg.padded_vocab, cfg.d_model, dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(k2, cfg.d_model, cfg.padded_vocab,
                                           dtype)
        if cfg.family == "vlm":
            n_groups = cfg.n_layers // cfg.cross_attn_every
            per_group_self = cfg.cross_attn_every - 1
            params["groups"] = stack_layers(
                lambda k: {
                    "self": stack_layers(
                        lambda kk: _layer_init(kk, cfg, dtype), k,
                        per_group_self),
                    "cross": _layer_init(jax.random.fold_in(k, 7), cfg, dtype,
                                         cross=True),
                }, k3, n_groups)
        else:
            params["layers"] = stack_layers(
                lambda k: _layer_init(k, cfg, dtype), k3, cfg.n_layers)
        return params
    if cfg.family == "ssm":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "embed": embed_init(k1, cfg.padded_vocab, cfg.d_model, dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
            "lm_head": dense_init(k2, cfg.d_model, cfg.padded_vocab, dtype),
            "layers": stack_layers(
                lambda k: _rwkv_layer_init(k, cfg, dtype), k3, cfg.n_layers),
        }
    if cfg.family == "hybrid":
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "embed": embed_init(k1, cfg.padded_vocab, cfg.d_model, dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
            "lm_head": dense_init(k2, cfg.d_model, cfg.padded_vocab, dtype),
            "layers": stack_layers(
                lambda k: {"ln": jnp.ones((cfg.d_model,), dtype),
                           "mamba": m2.mamba2_init(k, cfg, dtype)},
                k3, cfg.n_layers),
            "shared": _zamba_shared_init(k4, cfg, dtype),
        }
    if cfg.family == "audio":
        ks = jax.random.split(key, 6)
        return {
            "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
            "enc_pos": embed_init(ks[1], cfg.encoder_seq, cfg.d_model, dtype),
            "dec_pos": embed_init(ks[2], cfg.max_seq, cfg.d_model, dtype),
            "enc_layers": stack_layers(
                lambda k: _whisper_enc_layer_init(k, cfg, dtype), ks[3],
                cfg.encoder_layers),
            "dec_layers": stack_layers(
                lambda k: _whisper_dec_layer_init(k, cfg, dtype), ks[4],
                cfg.decoder_layers),
            "enc_norm_w": jnp.ones((cfg.d_model,), dtype),
            "enc_norm_b": jnp.zeros((cfg.d_model,), dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
            "final_norm_b": jnp.zeros((cfg.d_model,), dtype),
            "lm_head": dense_init(ks[5], cfg.d_model, cfg.padded_vocab, dtype),
        }
    raise ValueError(cfg.family)


def param_specs(cfg):
    if cfg.family in ("dense", "moe", "vlm"):
        specs: dict[str, Any] = {
            "embed": (VOCAB, EMBED), "final_norm": (EMBED,),
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = (EMBED, VOCAB)
        if cfg.family == "vlm":
            specs["groups"] = prepend_layers_axis({
                "self": prepend_layers_axis(_layer_specs(cfg)),
                "cross": _layer_specs(cfg, cross=True),
            })
        else:
            specs["layers"] = prepend_layers_axis(_layer_specs(cfg))
        return specs
    if cfg.family == "ssm":
        return {
            "embed": (VOCAB, EMBED), "final_norm": (EMBED,),
            "lm_head": (EMBED, VOCAB),
            "layers": prepend_layers_axis(_rwkv_layer_specs(cfg)),
        }
    if cfg.family == "hybrid":
        return {
            "embed": (VOCAB, EMBED), "final_norm": (EMBED,),
            "lm_head": (EMBED, VOCAB),
            "layers": prepend_layers_axis(
                {"ln": (EMBED,), "mamba": m2.mamba2_specs(cfg)}),
            "shared": _zamba_shared_specs(cfg),
        }
    if cfg.family == "audio":
        return {
            "embed": (VOCAB, EMBED),
            "enc_pos": ("frames", EMBED), "dec_pos": (None, EMBED),
            "enc_layers": prepend_layers_axis(_whisper_enc_layer_specs(cfg)),
            "dec_layers": prepend_layers_axis(_whisper_dec_layer_specs(cfg)),
            "enc_norm_w": (EMBED,), "enc_norm_b": (EMBED,),
            "final_norm": (EMBED,), "final_norm_b": (EMBED,),
            "lm_head": (EMBED, VOCAB),
        }
    raise ValueError(cfg.family)


def forward(params, cfg, tokens, extra=None, positions=None,
            with_aux: bool = False):
    """Teacher-forced logits (B, S, padded_vocab) fp32.

    with_aux=True returns (logits, moe_aux_loss) — aux is 0 for non-MoE.
    """
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "audio":
        enc_states = _whisper_encode(params, cfg, extra)
        x = _embed(params, cfg, tokens)
        x = x + params["dec_pos"][None, : tokens.shape[1], :]
        x, _ = _whisper_dec_stack(params, cfg, x, enc_states,
                                  positions=positions)
        out = _whisper_logits(params, cfg, x)
        return (out, aux) if with_aux else out
    x = _embed(params, cfg, tokens)
    if cfg.family in ("dense", "moe", "vlm"):
        collect = with_aux and cfg.family == "moe"
        res = _decoder_stack(params, cfg, x, positions=positions,
                             cross_states=extra, with_aux=collect)
        x = res[0]
        if collect:
            aux = res[2]
    elif cfg.family == "ssm":
        x, _ = _rwkv_stack(params, cfg, x)
    elif cfg.family == "hybrid":
        x, _ = _hybrid_stack(params, cfg, x, positions=positions)
    else:
        raise ValueError(cfg.family)
    out = _logits(params, cfg, x)
    return (out, aux) if with_aux else out


def init_cache(cfg, batch: int, max_seq: int, extra_len: int = 0):
    dtype = _dtype(cfg)
    if cfg.family in ("dense", "moe"):
        return {"layers": _stack_cache(
            _attn_cache_init(cfg, batch, max_seq, dtype), cfg.n_layers)}
    if cfg.family == "vlm":
        n_groups = cfg.n_layers // cfg.cross_attn_every
        per_group_self = cfg.cross_attn_every - 1
        one = _attn_cache_init(cfg, batch, max_seq, dtype)
        return {"layers": _stack_cache(
            {"self": _stack_cache(one, per_group_self)}, n_groups)}
    if cfg.family == "ssm":
        return {"layers": _stack_cache(_rwkv_zero_state(cfg, batch),
                                       cfg.n_layers)}
    if cfg.family == "hybrid":
        h = cfg.mamba_heads
        ph, n = cfg.mamba_d_inner // h, cfg.ssm_state
        w = cfg.mamba_conv_width
        n_inv = cfg.n_layers // cfg.hybrid_attn_every
        # shared-block cache stays unquantized (tiny; _hybrid_stack slices
        # k/v per invocation explicitly)
        shared_one = attn.gqa_cache_init(
            cfg.replace(kv_cache_quant=False) if cfg.kv_cache_quant else cfg,
            batch, max_seq, dtype)
        return {
            "mamba_state": jnp.zeros((cfg.n_layers, batch, h, ph, n),
                                     jnp.float32),
            "conv_tail": jnp.zeros(
                (cfg.n_layers, batch, w - 1, cfg.mamba_d_inner + 2 * n), dtype),
            "shared": {"k": _stack_cache(shared_one["k"], n_inv),
                       "v": _stack_cache(shared_one["v"], n_inv)},
            "idx": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "audio":
        one = _attn_cache_init(cfg, batch, max_seq, dtype)
        hk, hd = cfg.n_kv_heads, cfg.head_dim
        return {
            "layers": _stack_cache(one, cfg.decoder_layers),
            "cross": {
                "k": jnp.zeros((cfg.decoder_layers, batch, extra_len, hk, hd),
                               dtype),
                "v": jnp.zeros((cfg.decoder_layers, batch, extra_len, hk, hd),
                               dtype),
            },
        }
    raise ValueError(cfg.family)


def prefill(params, cfg, tokens, extra=None, cache=None):
    """Fill the cache with a teacher-forced pass; returns (logits, cache)."""
    b, s = tokens.shape
    if cache is None:
        cache = init_cache(cfg, b, cfg.max_seq,
                           extra.shape[1] if extra is not None else 0)
    positions = jnp.arange(s)[None, :]
    if cfg.family == "audio":
        enc_states = _whisper_encode(params, cfg, extra)

        def xkv(lp):
            k = jnp.einsum("btd,dhe->bthe", enc_states, lp["xattn"]["wk"])
            v = jnp.einsum("btd,dhe->bthe", enc_states, lp["xattn"]["wv"])
            return k.astype(_dtype(cfg)), v.astype(_dtype(cfg))

        ks, vs = jax.vmap(xkv)(params["dec_layers"])
        cross = {"k": ks, "v": vs}
        x = _embed(params, cfg, tokens) + params["dec_pos"][None, :s, :]
        x, new_l = _whisper_dec_stack(params, cfg, x, None,
                                      positions=positions,
                                      caches=cache["layers"],
                                      cross_cache=cross)
        return _whisper_logits(params, cfg, x), {"layers": new_l,
                                                 "cross": cross}
    x = _embed(params, cfg, tokens)
    if cfg.family in ("dense", "moe", "vlm"):
        x, new_l = _decoder_stack(params, cfg, x, positions=positions,
                                  caches=cache["layers"], cross_states=extra)
        return _logits(params, cfg, x), {"layers": new_l}
    if cfg.family == "ssm":
        x, new_l = _rwkv_stack(params, cfg, x, caches=cache["layers"])
        return _logits(params, cfg, x), {"layers": new_l}
    if cfg.family == "hybrid":
        x, new_c = _hybrid_stack(params, cfg, x, positions=positions,
                                 cache=cache)
        return _logits(params, cfg, x), new_c
    raise ValueError(cfg.family)


def decode_step(params, cfg, token, cache, extra=None):
    """token: (B, 1); one serving step against the cache."""
    b = token.shape[0]
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        idx = _cache_idx(cfg, cache)
        positions = jnp.broadcast_to(idx[None, None], (b, 1))
        if cfg.family == "audio":
            x = _embed(params, cfg, token)
            x = x + jnp.take(params["dec_pos"], positions, axis=0)
            x, new_l = _whisper_dec_stack(
                params, cfg, x, None, positions=positions,
                caches=cache["layers"], cross_cache=cache["cross"])
            return _whisper_logits(params, cfg, x), {
                "layers": new_l, "cross": cache["cross"]}
        x = _embed(params, cfg, token)
        x, new_l = _decoder_stack(params, cfg, x, positions=positions,
                                  caches=cache["layers"], cross_states=extra)
        return _logits(params, cfg, x), {"layers": new_l}
    x = _embed(params, cfg, token)
    if cfg.family == "ssm":
        x, new_l = _rwkv_stack(params, cfg, x, caches=cache["layers"])
        return _logits(params, cfg, x), {"layers": new_l}
    if cfg.family == "hybrid":
        positions = jnp.broadcast_to(cache["idx"][None, None], (b, 1))
        x, new_c = _hybrid_stack(params, cfg, x, positions=positions,
                                 cache=cache)
        return _logits(params, cfg, x), new_c
    raise ValueError(cfg.family)


def _cache_idx(cfg, cache):
    if cfg.family in ("dense", "moe", "audio"):
        return cache["layers"]["idx"][0]
    if cfg.family == "vlm":
        return cache["layers"]["self"]["idx"][0, 0]
    raise ValueError(cfg.family)
