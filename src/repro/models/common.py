"""Parameter plumbing shared by every model: init helpers and logical-axis
spec trees (nested dicts mirroring the param trees; leaves are tuples of
logical axis names consumed by distribution/sharding.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis names (mapped to mesh axes by distribution/sharding.py)
BATCH = "batch"
SEQ = "seq"
EMBED = "embed"          # d_model — FSDP-sharded on weights
FFN = "ffn"              # hidden ffn dim — TP-sharded
HEADS = "heads"          # q heads — TP-sharded
KV_HEADS = "kv_heads"    # kv heads — replicated when < TP degree
HEAD_DIM = "head_dim"
VOCAB = "vocab"          # TP-sharded
EXPERTS = "experts"      # EP-sharded
LAYERS = "layers"        # scan axis — never sharded
STATE = "state"          # ssm state dim
CAP = "capacity"


def dense_init(key, in_dim: int, out_dims, dtype, scale: float | None = None):
    """Truncated-normal init for a (in, *out) projection, fan-in scaled."""
    out_dims = (out_dims,) if isinstance(out_dims, int) else tuple(out_dims)
    if scale is None:
        scale = 1.0 / np.sqrt(in_dim)
    w = jax.random.truncated_normal(
        key, -2.0, 2.0, (in_dim, *out_dims), jnp.float32
    ) * scale
    return w.astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    w = jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
    return w.astype(dtype)


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * weight


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * weight + bias


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def mlp_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype),
        "up": dense_init(k2, d_model, d_ff, dtype),
        "down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp_specs():
    return {"gate": (EMBED, FFN), "up": (EMBED, FFN), "down": (FFN, EMBED)}


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding over the last dim of (..., seq, n_heads, head_dim)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def stack_layers(init_fn, key, n_layers: int):
    """Init n_layers instances and stack leaves on a leading `layers` axis."""
    keys = jax.random.split(key, n_layers)
    per_layer = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per_layer)


def prepend_layers_axis(spec_tree):
    """Add the scan `layers` axis in front of every leaf spec."""
    return jax.tree.map(
        lambda s: (LAYERS, *s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, tuple),
    )


def count_params(params) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree.leaves(params)))
