"""Attention variants: GQA (RoPE, optional qk-norm), MLA (DeepSeek-V2
compressed KV), and cross-attention.  Every variant supports full-sequence
(train / prefill) and single-step decode against a cache.

The XLA softmax path is *q-chunked* (scan over query blocks with per-block
masks built from positions, never materializing (S, T) probabilities — the
same block decomposition the paper's triangular map induces).  Peak memory
per layer is one (B, H, chunk, T) block.  The causal self-attention score
space is the paper's 2D lower-triangular domain; `cfg.attn_impl` selects:
  * "xla"           — chunked einsum + mask (dry-run/compile analysis path),
  * "pallas_mapped" — mapped linear-λ-grid Pallas kernel (paper technique),
  * "pallas_bb"     — bounding-box Pallas kernel (paper baseline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distribution.sharding import logical_constraint as lc
from repro.models.common import (
    EMBED, HEAD_DIM, HEADS, KV_HEADS, dense_init, rms_norm, rope,
)

NEG_INF = -1e30
_Q_CHUNK = 256


def _sdpa(q, k, v, n_kv_heads, q_pos=None, chunk: int = _Q_CHUNK,
          logit_dim: int | None = None):
    """Grouped SDPA, fp32 softmax, q-chunked.

    q: (B, S, H, D); k, v: (B, T, Hk, D).
    q_pos: (B, S) absolute positions — causal mask "kv_index <= q_pos";
           None => no mask (cross / bidirectional attention).
    logit_dim: scale denominator (defaults to D — MLA passes nope+rope).
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    dv = v.shape[-1]
    g = h // n_kv_heads
    scale = (logit_dim or d) ** -0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kv_idx = jnp.arange(t)

    def block(q_blk, pos_blk):
        """q_blk: (B, C, H, D); pos_blk: (B, C) or None."""
        qg = q_blk.reshape(b, -1, n_kv_heads, g, d).astype(jnp.float32)
        logits = jnp.einsum("bskgd,btkd->bkgst", qg, kf) * scale
        if pos_blk is not None:
            mask = kv_idx[None, :] <= pos_blk[..., None]      # (B, C, T)
            logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgst,btkd->bskgd", probs, vf)
        return out.reshape(b, -1, h, dv).astype(q.dtype)

    if s <= chunk or s % chunk != 0:
        return block(q, q_pos)

    nc = s // chunk
    qc = q.reshape(b, nc, chunk, h, d).transpose(1, 0, 2, 3, 4)
    pc = (None if q_pos is None
          else q_pos.reshape(b, nc, chunk).transpose(1, 0, 2))

    def step(_, inp):
        q_blk, pos_blk = inp
        return None, jax.checkpoint(block)(q_blk, pos_blk)

    _, out = jax.lax.scan(step, None, (qc, pc))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dv)


def _jnp_tri_ij(lam):
    """Paper Table-I map on traced scalars: λ -> (i, j), i >= j."""
    v = 8 * lam + 1
    r = jnp.sqrt(v.astype(jnp.float32)).astype(jnp.int32)
    for _ in range(2):
        r = jnp.where((r + 1) * (r + 1) <= v, r + 1, r)
        r = jnp.where(r * r > v, r - 1, r)
    i = (r - 1) // 2
    return i, lam - i * (i + 1) // 2


def _sdpa_mapped_causal(q, k, v, n_kv_heads, chunk: int = _Q_CHUNK):
    """Causal SDPA over the *mapped triangular block grid* (pure XLA).

    The (q_block i, k_block j) iteration space is enumerated linearly with
    the paper's inverse-triangular map.  Because nb is static, λ -> (i, j)
    is evaluated at TRACE time (numpy!) — the block-pair axis becomes a
    batched dimension with static gather indices, which:
      * computes exactly T(nb)=nb(nb+1)/2 block pairs (no BB waste),
      * is shardable over the tensor axis (`attn_seq` rule) — sequence
        parallelism for heads counts that don't divide the mesh,
      * combines rows with a segment-softmax over the static row ids.
    Exact (fp32 softmax), differentiable, scan-free.
    """
    import numpy as np

    b, s, h, d = q.shape
    dv = v.shape[-1]
    g = h // n_kv_heads
    scale = d ** -0.5
    nb = s // chunk
    assert s % chunk == 0
    npairs = nb * (nb + 1) // 2
    lam = np.arange(npairs)
    i_np = ((np.sqrt(8 * lam + 1).astype(np.int64) - 1) // 2)
    i_np += ((i_np + 2) * (i_np + 1) // 2 <= lam)   # exactness correction
    j_np = lam - i_np * (i_np + 1) // 2
    diag_mask = np.tril(np.ones((chunk, chunk), bool))
    pair_mask = np.where((i_np == j_np)[:, None, None], diag_mask[None],
                         True)                       # (L, C, C) static
    # pad the pair axis to a 16 multiple so it stays shardable on the
    # tensor axis (fully-masked dummy pairs contribute exactly zero)
    pad = (-npairs) % 16
    if pad:
        i_np = np.concatenate([i_np, np.zeros(pad, np.int64)])
        j_np = np.concatenate([j_np, np.zeros(pad, np.int64)])
        pair_mask = np.concatenate(
            [pair_mask, np.zeros((pad, chunk, chunk), bool)], axis=0)

    qg = q.reshape(b, nb, chunk, n_kv_heads, g, d)
    kg = k.reshape(b, nb, chunk, n_kv_heads, d)
    vg = v.reshape(b, nb, chunk, n_kv_heads, dv)
    qp = jnp.take(qg, i_np, axis=1)                 # (B, L, C, kv, g, d)
    kp = jnp.take(kg, j_np, axis=1)
    vp = jnp.take(vg, j_np, axis=1)
    qp = lc(qp, "batch", "attn_seq", None, None, None, None)
    kp = lc(kp, "batch", "attn_seq", None, None, None)
    vp = lc(vp, "batch", "attn_seq", None, None, None)

    logits = jnp.einsum("blskgd,bltkd->blkgst", qp.astype(jnp.float32),
                        kp.astype(jnp.float32)) * scale  # (B,L,kv,g,C,C)
    logits = jnp.where(pair_mask[None, :, None, None, :, :], logits, NEG_INF)

    m_pair = logits.max(axis=-1)                    # (B, L, kv, g, C)
    m_row = jax.ops.segment_max(m_pair.swapaxes(0, 1), i_np,
                                num_segments=nb)    # (nb, B, kv, g, C)
    m_full = jnp.take(m_row, i_np, axis=0).swapaxes(0, 1)
    p = jnp.exp(logits - m_full[..., None])
    l_pair = p.sum(axis=-1)
    l_row = jax.ops.segment_sum(l_pair.swapaxes(0, 1), i_np,
                                num_segments=nb)
    o_pair = jnp.einsum("blkgst,bltkd->blkgsd", p, vp.astype(jnp.float32))
    o_row = jax.ops.segment_sum(o_pair.swapaxes(0, 1), i_np,
                                num_segments=nb)    # (nb, B, kv, g, C, dv)
    out = o_row / jnp.maximum(l_row, 1e-30)[..., None]
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, h, dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], d, (h, hd), dtype),
        "wk": dense_init(ks[1], d, (hk, hd), dtype),
        "wv": dense_init(ks[2], d, (hk, hd), dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def gqa_specs(cfg):
    s = {
        "wq": (EMBED, HEADS, None),
        "wk": (EMBED, KV_HEADS, None),
        "wv": (EMBED, KV_HEADS, None),
        "wo": (HEADS, EMBED),  # fused (h*hd) input dim — sharded like heads
    }
    if cfg.qk_norm:
        s["q_norm"] = (HEAD_DIM,)
        s["k_norm"] = (HEAD_DIM,)
    return s


def _pallas_causal(q, k, v, grid_mode, block, interpret):
    from repro.kernels.tri_attn.ops import causal_attention

    qt, kt, vt = (x.swapaxes(1, 2) for x in (q, k, v))  # -> (B, H, S, D)
    out = causal_attention(qt, kt, vt, block, block, grid_mode, interpret)
    return out.swapaxes(1, 2)


def gqa_apply(p, cfg, x, *, positions=None, cache=None, cross_kv=None):
    """Returns (out, new_cache). x: (B, S, d)."""
    b, s, _ = x.shape
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    if cross_kv is None:
        k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
        v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    else:  # cross-attention: kv from encoder/vision states
        k = jnp.einsum("btd,dhe->bthe", cross_kv, p["wk"])
        v = jnp.einsum("btd,dhe->bthe", cross_kv, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    else:
        positions = jnp.broadcast_to(positions, (b, s))
    if cross_kv is None and cfg.rope_theta:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)  # same positions: k is from x
    # attn_seq -> model gives sequence-parallel attention when the head
    # count doesn't divide the tensor axis (divisibility fallback case)
    q = lc(q, "batch", "attn_seq", "heads", None)

    new_cache = None
    q_pos = positions
    if cross_kv is not None:
        q_pos = None                       # rectangular box domain — no mask
    elif cache is not None:                # decode/prefill against cache
        idx = cache["idx"]
        new_cache = {
            **_cache_put(cache, "k", k, idx),
            **_cache_put(cache, "v", v, idx),
            "idx": idx + s,
        }
        k = _cache_get(new_cache, "k", x.dtype)
        v = _cache_get(new_cache, "v", x.dtype)
    k = lc(k, "batch", "kv_seq", "kv_heads", None)
    v = lc(v, "batch", "kv_seq", "kv_heads", None)

    if (cache is None and cross_kv is None
            and cfg.attn_impl in ("pallas_mapped", "pallas_bb")
            and s % cfg.attn_block == 0 and s >= cfg.attn_block):
        grid_mode = "mapped" if cfg.attn_impl == "pallas_mapped" else "bounding_box"
        kr = jnp.repeat(k, h // hk, axis=2) if hk != h else k
        vr = jnp.repeat(v, h // hk, axis=2) if hk != h else v
        out = _pallas_causal(q, kr, vr, grid_mode, cfg.attn_block,
                             cfg.pallas_interpret)
    elif (cache is None and cross_kv is None and cfg.attn_impl == "xla_mapped"
            and s % _Q_CHUNK == 0 and s > _Q_CHUNK):
        out = _sdpa_mapped_causal(q, k, v, hk, _Q_CHUNK)
    else:
        out = _sdpa(q, k, v, hk, q_pos)
    out = lc(out, "batch", None, "heads", None)
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"].reshape(h, hd, cfg.d_model))
    return y, new_cache


def _quantize_rows(t):
    """absmax int8 quantization over the last dim: (values, scales)."""
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-8) / 127.0
    q = jnp.round(t.astype(jnp.float32) / scale).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _cache_put(cache, key, val, idx, ndim4: bool = True):
    """Insert `val` at position idx, quantizing when the cache is int8."""
    store = cache[key]
    if store.dtype == jnp.int8:
        q, scale = _quantize_rows(val)
        start = (0, idx, 0, 0) if ndim4 else (0, idx, 0)
        new = jax.lax.dynamic_update_slice(store, q, start)
        new_s = jax.lax.dynamic_update_slice(
            cache[key + "_scale"], scale, start)
        return {key: new, key + "_scale": new_s}
    start = (0, idx, 0, 0) if ndim4 else (0, idx, 0)
    return {key: jax.lax.dynamic_update_slice(
        store, val.astype(store.dtype), start)}


def _cache_get(entries, key, dtype):
    """Read (dequantize if int8) a cache tensor."""
    t = entries[key]
    if t.dtype == jnp.int8:
        return (t.astype(jnp.float32) * entries[key + "_scale"]).astype(dtype)
    return t


def gqa_cache_init(cfg, batch: int, max_seq: int, dtype):
    hk, hd = cfg.n_kv_heads, cfg.head_dim
    if cfg.kv_cache_quant:
        return {
            "k": jnp.zeros((batch, max_seq, hk, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, max_seq, hk, 1), jnp.float32),
            "v": jnp.zeros((batch, max_seq, hk, hd), jnp.int8),
            "v_scale": jnp.zeros((batch, max_seq, hk, 1), jnp.float32),
            "idx": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, max_seq, hk, hd), dtype),
        "v": jnp.zeros((batch, max_seq, hk, hd), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank compressed KV cache + decoupled RoPE key
# ---------------------------------------------------------------------------


def mla_init(key, cfg, dtype):
    ks = jax.random.split(key, 8)
    d, h = cfg.d_model, cfg.n_heads
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wdq": dense_init(ks[0], d, ql, dtype),
        "q_norm": jnp.ones((ql,), dtype),
        "wuq": dense_init(ks[1], ql, (h, dn + dr), dtype),
        "wdkv": dense_init(ks[2], d, kl, dtype),
        "kv_norm": jnp.ones((kl,), dtype),
        "wuk": dense_init(ks[3], kl, (h, dn), dtype),
        "wuv": dense_init(ks[4], kl, (h, dv), dtype),
        "wkr": dense_init(ks[5], d, dr, dtype),
        "wo": dense_init(ks[6], h * dv, d, dtype),
    }


def mla_specs(cfg):
    return {
        "wdq": (EMBED, "q_lora"),
        "q_norm": ("q_lora",),
        "wuq": ("q_lora", HEADS, None),
        "wdkv": (EMBED, "kv_lora"),
        "kv_norm": ("kv_lora",),
        "wuk": ("kv_lora", HEADS, None),
        "wuv": ("kv_lora", HEADS, None),
        "wkr": (EMBED, None),
        "wo": (HEADS, EMBED),
    }


def mla_apply(p, cfg, x, *, positions=None, cache=None, cross_kv=None):
    assert cross_kv is None
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    else:
        positions = jnp.broadcast_to(positions, (b, s))

    q = jnp.einsum("bsl,lhe->bshe",
                   rms_norm(jnp.einsum("bsd,dl->bsl", x, p["wdq"]), p["q_norm"]),
                   p["wuq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)   # (B,S,H,dn+dr)
    q_full = lc(q_full, "batch", None, "heads", None)

    ckv = jnp.einsum("bsd,dl->bsl", x, p["wdkv"])          # compressed kv
    krope = rope(jnp.einsum("bsd,dr->bsr", x, p["wkr"])[:, :, None, :],
                 positions, cfg.rope_theta)[:, :, 0, :]    # shared rope key

    new_cache = None
    if cache is not None:
        idx = cache["idx"]
        new_cache = {
            **_cache_put(cache, "ckv", ckv, idx, ndim4=False),
            **_cache_put(cache, "krope", krope, idx, ndim4=False),
            "idx": idx + s,
        }
        ckv = _cache_get(new_cache, "ckv", x.dtype)
        krope = _cache_get(new_cache, "krope", x.dtype)
    ckv = lc(ckv, "batch", "kv_seq", "kv_lora")
    ckv_n = rms_norm(ckv, p["kv_norm"])
    t = ckv_n.shape[1]

    absorb = (cfg.mla_absorb != "never" and cache is not None and s <= 32)
    if absorb:
        # weight absorption (decode): move W_uk onto the query and keep
        # attention in the compressed kv_lora space — the per-step
        # up-projection of the whole cache (2·T·kl·H·(dn+dv) flops) vanishes.
        #   q·k = (W_uk q_nope)·c_kv ;  probs·v = (probs·c_kv)·W_uv
        scale = (dn + dr) ** -0.5
        q_abs = jnp.einsum("bshe,lhe->bshl", q_nope, p["wuk"])
        logits = (
            jnp.einsum("bshl,btl->bhst", q_abs.astype(jnp.float32),
                       ckv_n.astype(jnp.float32))
            + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                         krope.astype(jnp.float32))
        ) * scale
        idx = new_cache["idx"] - s
        mask = (jnp.arange(t)[None, None, :]
                <= idx + jnp.arange(s)[None, :, None])
        logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bhst,btl->bshl", probs,
                           ckv_n.astype(jnp.float32))
        out = jnp.einsum("bshl,lhe->bshe", o_lat.astype(x.dtype), p["wuv"])
        y = jnp.einsum("bsf,fd->bsd", out.reshape(b, s, h * dv), p["wo"])
        return y, new_cache

    k_nope = jnp.einsum("btl,lhe->bthe", ckv_n, p["wuk"])  # (B,T,H,dn)
    v = jnp.einsum("btl,lhe->bthe", ckv_n, p["wuv"])       # (B,T,H,dv)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :], (b, t, h, dr))],
        axis=-1)
    k_full = lc(k_full, "batch", "kv_seq", "heads", None)
    v = lc(v, "batch", "kv_seq", "heads", None)

    out = _sdpa(q_full, k_full, v, h, positions, logit_dim=dn + dr)
    out = out.reshape(b, s, h * dv)
    y = jnp.einsum("bsf,fd->bsd", out, p["wo"])
    return y, new_cache


def mla_cache_init(cfg, batch: int, max_seq: int, dtype):
    # int8 quantization is NOT offered for MLA: the compressed latent is
    # already ~14x smaller than a GQA cache, and the rms_norm + up-projection
    # amplify absmax-int8 noise to ~8% logits error (measured) — the
    # compression budget is spent. kv_cache_quant therefore applies to GQA
    # caches only.
    return {
        "ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }
