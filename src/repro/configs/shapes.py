"""Assigned input shapes (one set shared by all 10 LM archs).

``decode_*`` / ``long_*`` lower ``serve_step`` (one token against a KV cache
of seq_len), NOT ``train_step``.  ``long_500k`` requires sub-quadratic
sequence mixing and only runs for SSM/hybrid archs (see DESIGN.md
§Arch-applicability for the 8 documented skips).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def applicable(cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: long_500k requires sub-quadratic "
                       "sequence mixing (assignment spec; see DESIGN.md)")
    return True, ""
