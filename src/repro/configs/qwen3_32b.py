"""qwen3-32b [dense] — qk_norm, GQA kv=8.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen3-32b"

CONFIG = ModelConfig(
    arch_id=ARCH_ID, family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=25600, vocab_size=151936, rope_theta=1000000.0, qk_norm=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, max_seq=64, dtype="float32",
    )
