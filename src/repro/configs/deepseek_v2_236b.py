"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]
Deviation: the paper's first dense layer is modeled as MoE (homogeneous
scan-over-layers); MLA dims are the published ones (q_lora 1536, kv_lora 512,
nope 128, rope 64, v 128).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "deepseek-v2-236b"

CONFIG = ModelConfig(
    arch_id=ARCH_ID, family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=1536, vocab_size=102400, rope_theta=10000.0,
    attention_type="mla",
    q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    n_experts=160, moe_top_k=6, expert_d_ff=1536, n_shared_experts=2,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=96, vocab_size=256,
        q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16,
        n_experts=8, moe_top_k=2, expert_d_ff=96, n_shared_experts=1,
        max_seq=64, dtype="float32",
    )
