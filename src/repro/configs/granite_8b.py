"""granite-8b [dense] — llama-arch, code model.  [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "granite-8b"

CONFIG = ModelConfig(
    arch_id=ARCH_ID, family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=49152, rope_theta=10000000.0,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, max_seq=64, dtype="float32",
    )
