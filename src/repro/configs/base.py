"""ModelConfig — the single config dataclass every architecture instantiates.

One file per assigned architecture lives next to this module; each exposes
``CONFIG`` (full-size, exact published dims) and ``smoke_config()`` (reduced
same-family config for CPU tests).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class ModelConfig:
    arch_id: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    rope_theta: float = 10000.0
    qk_norm: bool = False
    attention_type: str = "gqa"     # gqa | mla | none
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # --- MLA (deepseek-v2) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mla_absorb: str = "auto"        # auto (decode steps) | never
    kv_cache_quant: bool = False    # int8 KV cache (absmax per row)
    # --- MoE ---
    n_experts: int = 0
    moe_top_k: int = 0
    expert_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_renormalize: bool = True
    moe_groups: int = 1             # >1: group-local dispatch (G = data axis)
    moe_impl: str = "global"        # global | grouped | a2a (shard_map EP)
    # --- RWKV6 ---
    rwkv_heads: int = 0
    rwkv_decay_lora: int = 64
    # --- Mamba2 / hybrid ---
    ssm_state: int = 0
    mamba_d_inner: int = 0
    mamba_heads: int = 0
    mamba_conv_width: int = 4
    hybrid_attn_every: int = 0      # zamba2: shared attn block period
    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    decoder_layers: int = 0
    encoder_seq: int = 1500         # whisper conv-stub output frames
    # --- vlm ---
    cross_attn_every: int = 0       # llama-3.2-vision: 1 cross per 5 layers
    vision_seq: int = 4100          # stub patch embeddings (4 tiles x 1025)
    # --- kernels / numerics ---
    attn_impl: str = "xla"          # xla | pallas_mapped | pallas_bb
    attn_block: int = 128
    pallas_interpret: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"      # full (recompute all) | dots | none
    scan_layers: bool = True
    # --- shapes ---
    max_seq: int = 4096

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab_size // 128) * 128

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports long-context decode (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
