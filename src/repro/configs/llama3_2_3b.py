"""llama3.2-3b [dense] — small llama3, tied embeddings.
[hf:meta-llama/Llama-3.2-1B; unverified]
24 heads is not divisible by the 16-wide model axis: the sharding layer's
divisibility fallback replicates the head axis and keeps FSDP on embed.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "llama3.2-3b"

CONFIG = ModelConfig(
    arch_id=ARCH_ID, family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=128256, rope_theta=500000.0, tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=48, n_heads=6, n_kv_heads=2, head_dim=8,
        d_ff=96, vocab_size=256, max_seq=64, dtype="float32",
    )
