"""moonshot-v1-16b-a3b [moe] — kimi/moonlight-style, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]
Deviation: Moonlight's first dense layer is modeled as MoE like the rest
(homogeneous scan; <0.5% param delta — see DESIGN.md).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "moonshot-v1-16b-a3b"

CONFIG = ModelConfig(
    arch_id=ARCH_ID, family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=163840, rope_theta=50000.0,
    n_experts=64, moe_top_k=6, expert_d_ff=1408, n_shared_experts=2,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=96, vocab_size=256, n_experts=8, moe_top_k=2, expert_d_ff=96,
        n_shared_experts=1, max_seq=64, dtype="float32",
    )
