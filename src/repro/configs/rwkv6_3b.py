"""rwkv6-3b [ssm] — Finch, data-dependent decay; attention-free.
[arXiv:2404.05892; hf]
The paper's thread-mapping technique targets attention grids and is
inapplicable here (DESIGN.md §Arch-applicability); runs long_500k.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "rwkv6-3b"

CONFIG = ModelConfig(
    arch_id=ARCH_ID, family="ssm",
    n_layers=32, d_model=2560, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=8960, vocab_size=65536, rope_theta=0.0,
    attention_type="none", rwkv_heads=40, rwkv_decay_lora=64,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, d_ff=128, vocab_size=256, rwkv_heads=4,
        rwkv_decay_lora=16, max_seq=64, dtype="float32",
    )
