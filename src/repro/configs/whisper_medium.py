"""whisper-medium [audio] — enc-dec, conv frontend STUB (precomputed frame
embeddings via input_specs).  [arXiv:2212.04356; unverified]
"24L" = 24 encoder + 24 decoder layers (whisper-medium's published config).
Decoder shapes drive seq_len; encoder is fixed at 1500 frames.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "whisper-medium"

CONFIG = ModelConfig(
    arch_id=ARCH_ID, family="audio",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51865, rope_theta=0.0,
    is_encoder_decoder=True, encoder_layers=24, decoder_layers=24,
    encoder_seq=1500,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
        encoder_layers=2, decoder_layers=2, encoder_seq=24,
        max_seq=64, dtype="float32",
    )
