"""yi-6b [dense] — llama-arch GQA kv=4.  [arXiv:2403.04652; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "yi-6b"

CONFIG = ModelConfig(
    arch_id=ARCH_ID, family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64000, rope_theta=5000000.0,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, max_seq=64, dtype="float32",
    )
