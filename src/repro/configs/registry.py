"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

_MODULES = {
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "yi-6b": "repro.configs.yi_6b",
    "llama3.2-3b": "repro.configs.llama3_2_3b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "granite-8b": "repro.configs.granite_8b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "whisper-medium": "repro.configs.whisper_medium",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch_id]).smoke_config()
