"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]
Deviation: Zamba2's shared block is invoked with per-invocation LoRA
adapters; we model the shared weights without LoRA (see DESIGN.md).
Runs long_500k (sub-quadratic decode).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "zamba2-1.2b"

CONFIG = ModelConfig(
    arch_id=ARCH_ID, family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000, rope_theta=10000.0,
    ssm_state=64, mamba_d_inner=4096, mamba_heads=64, mamba_conv_width=4,
    hybrid_attn_every=6,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
        ssm_state=16, mamba_d_inner=128, mamba_heads=8,
        hybrid_attn_every=3, max_seq=64, dtype="float32",
    )
