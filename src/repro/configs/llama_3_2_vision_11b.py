"""llama-3.2-vision-11b [vlm] — 40L (32 self + 8 gated cross-attn image
layers, 1 per 5-layer group), GQA kv=8.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
Vision frontend is a STUB: input_specs provides precomputed patch embeddings.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "llama-3.2-vision-11b"

CONFIG = ModelConfig(
    arch_id=ARCH_ID, family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256, rope_theta=500000.0,
    cross_attn_every=5, vision_seq=4100,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=10, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, vision_seq=12, max_seq=64, dtype="float32",
    )
