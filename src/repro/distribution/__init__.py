"""Distribution layer: logical-axis sharding rules, collectives, compression."""
