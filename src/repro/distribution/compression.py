"""Gradient compression for cross-pod data parallelism.

int8 block-quantized all-reduce: each gradient tensor is chunked, quantized
to int8 against a per-chunk absmax scale, summed across the axis in int32,
and dequantized.  On a real fabric this cuts the DCN/cross-pod all-reduce
bytes 4x (bf16 -> int8 payload + fp32 scales/chunk); semantics (bounded
quantization error, exact zero preservation) are validated in tests.

Implemented with shard_map so the collective is explicit — the gradient tree
is expected to be *replicated* over the compressed axis inside the mapped
function (the usual DP gradient layout before the all-reduce).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

CHUNK = 256


def _quantize(x: jnp.ndarray):
    """x fp -> (int8 values, fp32 scales) with per-chunk absmax scaling."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % CHUNK
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, CHUNK)
    scale = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) / 127.0
    q = jnp.round(chunks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale, shape, dtype):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    size = 1
    for s in shape:
        size *= s
    return flat[:size].reshape(shape).astype(dtype)


def compressed_psum_mean_leaf(x, axis_name: str, axis_size: int):
    """Mean-all-reduce one tensor over `axis_name` via int8 quantization.

    A shared per-chunk scale (pmax of local absmax) makes the quantized sum
    exact up to the int8 rounding of each replica:
        result = psum(round(x / s)) * s / n.
    """
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % CHUNK
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, CHUNK)
    local_scale = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) / 127.0
    scale = jax.lax.pmax(local_scale, axis_name)           # shared scale
    q = jnp.round(chunks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)    # wide accumulate
    return _dequantize(qsum, scale / axis_size, x.shape, x.dtype)


def compressed_psum_mean(tree, axis_name: str, axis_size: int):
    return jax.tree.map(
        functools.partial(compressed_psum_mean_leaf, axis_name=axis_name,
                          axis_size=axis_size), tree)


def quantization_error_bound(x) -> float:
    """Worst-case per-element absolute error of one quantize/dequantize."""
    q, scale = _quantize(x)
    return float(jnp.max(scale)) * 0.5
