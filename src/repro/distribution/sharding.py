"""Logical-axis sharding: params and activations carry *logical* axis names
(models/common.py); this module resolves them onto mesh axes per a rule set.

Resolution is best-effort: a logical axis whose dimension is not divisible by
the product of its mesh axes is dropped (replicated) rather than erroring —
the divisibility fallback that lets e.g. a 24-head model run on a 16-wide
tensor axis (the weight stays FSDP-sharded on `embed`).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (str), tuple of mesh axes, or None (replicated)
PARAM_RULES: dict[str, Any] = {
    "batch": None, "seq": None,
    "embed": "data",          # FSDP/ZeRO-3: weights + opt state sharded on data
    "ffn": "model",           # TP
    "heads": "model",         # TP
    "kv_heads": None,         # GQA kv groups are narrower than the TP axis
    "head_dim": None,
    "vocab": "model",         # TP on embedding/lm_head
    "experts": "model",       # EP
    "layers": None,           # scan axis
    "state": None, "capacity": None, "kv_lora": None, "q_lora": None,
    "conv": None, "frames": None, "experts_group": None, "attn_seq": None,
}

# activation rules (training / prefill): batch data-parallel over pod+data
ACT_RULES: dict[str, Any] = {
    **{k: None for k in PARAM_RULES},
    "batch": ("pod", "data"),
    "heads": "model", "ffn": "model", "vocab": "model", "experts": "model",
    "embed": None, "kv_seq": None,
    "experts_group": ("pod", "data"),  # grouped MoE dispatch locality
    "attn_seq": None,                  # optional SP for unshardable heads
}

# activation rules for long-context decode (batch too small to shard):
# sequence-parallel KV cache over the data axis.
ACT_RULES_SP: dict[str, Any] = {
    **ACT_RULES,
    "batch": None,
    "kv_seq": "data",
    "seq": None,
}


@dataclasses.dataclass
class ShardingCtx:
    mesh: Mesh
    param_rules: dict[str, Any]
    act_rules: dict[str, Any]


_STATE = threading.local()


def current_ctx() -> ShardingCtx | None:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh, param_rules=None, act_rules=None):
    prev = current_ctx()
    _STATE.ctx = ShardingCtx(
        mesh=mesh,
        param_rules=dict(param_rules or PARAM_RULES),
        act_rules=dict(act_rules or ACT_RULES),
    )
    try:
        with mesh:
            yield _STATE.ctx
    finally:
        _STATE.ctx = prev


def _mesh_axes_for(logical: str, rules: dict, mesh: Mesh):
    mapped = rules.get(logical)
    if mapped is None:
        return None
    axes = (mapped,) if isinstance(mapped, str) else tuple(mapped)
    axes = tuple(a for a in axes if a in mesh.shape)
    return axes or None


def resolve_spec(logical_axes: tuple, rules: dict, mesh: Mesh,
                 shape: tuple | None = None) -> P:
    """Logical axes tuple -> PartitionSpec, with divisibility fallback."""
    used: set[str] = set()
    parts = []
    for d, name in enumerate(logical_axes):
        if name is None:
            parts.append(None)
            continue
        axes = _mesh_axes_for(name, rules, mesh)
        if axes is None:
            parts.append(None)
            continue
        axes = tuple(a for a in axes if a not in used)
        if not axes:
            parts.append(None)
            continue
        if shape is not None:
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if shape[d] % size != 0:
                parts.append(None)  # divisibility fallback: replicate
                continue
        used.update(axes)
        parts.append(axes if len(axes) > 1 else axes[0])
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_sharding(spec_tree, params, mesh: Mesh | None = None,
                   rules: dict | None = None):
    """Param logical-spec tree -> NamedSharding tree (shape-aware)."""
    ctx = current_ctx()
    mesh = mesh or (ctx.mesh if ctx else None)
    rules = rules or (ctx.param_rules if ctx else PARAM_RULES)
    if mesh is None:
        raise ValueError("no mesh: call inside use_sharding() or pass mesh=")

    def one(spec, p):
        return NamedSharding(mesh, resolve_spec(spec, rules, mesh, p.shape))

    return jax.tree.map(
        one, spec_tree, params, is_leaf=lambda s: isinstance(s, tuple)
    )


def logical_constraint(x, *logical_axes):
    """with_sharding_constraint by logical names; identity outside a mesh."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = resolve_spec(tuple(logical_axes), ctx.act_rules, ctx.mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
