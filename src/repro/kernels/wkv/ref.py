"""Pure-jnp oracle for the WKV recurrence (RWKV6 core).

    o_t = r_t^T S_{t-1} + (u ⊙ r_t)·k_t v_t
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, w, u, state):
    """r,k,v,w: (BH, S, D) fp32; u: (BH, D); state: (BH, D, D).

    Returns (o: (BH, S, D), final state).
    """

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (BH, D)
        o_t = jnp.einsum("bk,bkv->bv", r_t, S) + \
            jnp.einsum("bk,bk,bv->bv", r_t * u, k_t, v_t)
        S = w_t[..., None] * S + k_t[..., None] * v_t[:, None, :]
        return S, o_t

    xs = tuple(t.swapaxes(0, 1) for t in (r, k, v, w))
    state_f, o = jax.lax.scan(step, state, xs)
    return o.swapaxes(0, 1), state_f
