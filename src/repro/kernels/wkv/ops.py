"""Jit'd wrapper for the chunked WKV kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.wkv.kernel import build_wkv_call


def wkv_chunked(r, k, v, w, u, state, chunk: int = 64,
                interpret: bool = False):
    """r,k,v,w: (BH, S, D); u: (BH, D); state: (BH, D, D) fp32.

    w is the decay in (0, 1); the kernel consumes log(w).
    Returns (o: (BH, S, D) in r.dtype, final state fp32).
    """
    bh, s, d = r.shape
    call = build_wkv_call(bh, s, d, chunk=chunk, dtype=r.dtype,
                          interpret=interpret)
    logw = jnp.log(w.astype(jnp.float32))
    o, s_out = call(r, k, v, logw, u[:, None, :].astype(jnp.float32),
                    state.astype(jnp.float32))
    return o, s_out
