from repro.kernels.wkv.ops import wkv_chunked  # noqa: F401
from repro.kernels.wkv.ref import wkv_ref  # noqa: F401
