"""Chunked WKV Pallas kernel (RWKV6 time-mix hot spot).

Grid: (batch*heads, n_chunks) — sequential chunk steps per core with the
(D, D) state carried in fp32 VMEM scratch.  Per step, the chunkwise-parallel
form of the recurrence (see models/rwkv6.py):

    A_t   = cumprod(w) within the chunk         (per key dim)
    o     = tril_strict(r̃ k̃^T) V + diag((u⊙r)·k) V + r̃ S_in
    S_out = A_C ⊙ (S_in + k̃^T V)
with r̃ = r ⊙ A_{t-1}, k̃ = k / A_t — the strictly-lower-triangular intra
matmul is the paper's 2D block domain at chunk granularity.

VMEM per step: 4 (C, D) input tiles + (C, C) pair matrix + (D, D) state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, logw_ref, u_ref, s0_ref,
                o_ref, s_out_ref, s_scr, *, chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_scr[...] = s0_ref[0]

    r = r_ref[0].astype(jnp.float32)          # (C, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    logw = logw_ref[0].astype(jnp.float32)    # log decay, < 0
    u = u_ref[0].astype(jnp.float32)          # (1, D) bonus

    clog = jnp.cumsum(logw, axis=0)           # (C, D)
    a_prev = jnp.exp(clog - logw)             # A_{t-1} = A_t / w_t
    a_end = jnp.exp(clog[-1:])                # (1, D)

    r_t = r * a_prev
    k_t = k * jnp.exp(-clog)

    pmat = jax.lax.dot_general(               # (C, C)
        r_t, k_t, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, pmat.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, pmat.shape, 1)
    pmat = jnp.where(rows > cols, pmat, 0.0)  # strictly lower
    diag = jnp.sum(r * u * k, axis=1, keepdims=True)   # (C, 1)

    s_in = s_scr[...]                          # (D, D)
    o = (jax.lax.dot_general(pmat, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + diag * v
         + jax.lax.dot_general(r_t, s_in, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32))
    o_ref[0] = o.astype(o_ref.dtype)

    kv = jax.lax.dot_general(k_t, v, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (D, D)
    s_scr[...] = a_end.T * (s_in + kv)

    @pl.when(c == pl.num_programs(1) - 1)
    def _final():
        s_out_ref[0] = s_scr[...]


def build_wkv_call(bh: int, seq: int, d: int, *, chunk: int, dtype,
                   interpret: bool = False):
    assert seq % chunk == 0
    nc = seq // chunk
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),   # r
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),   # k
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),   # v
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),   # logw
            pl.BlockSpec((1, 1, d), lambda b, c: (b, 0, 0)),       # u
            pl.BlockSpec((1, d, d), lambda b, c: (b, 0, 0)),       # s0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),   # o
            pl.BlockSpec((1, d, d), lambda b, c: (b, 0, 0)),       # s_out
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, seq, d), dtype),
            jax.ShapeDtypeStruct((bh, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )
