"""In-kernel (Pallas-traceable) geometry tiers, registered per domain.

Each domain registers two tiers into the MapRegistry:

  pallas      ``f(lam_block, ndigits) -> [axis arrays]`` — the vectorized
              Table-I map evaluated on a VMEM block of linear indices
              (integer VPU ops only, no gathers),
  membership  ``f(axes, ndigits) -> bool mask`` — the bounding-box kernel's
              discard condition.

All digit→vector tables are evaluated arithmetically (no gathers): e.g. the
Menger digit d maps to the row-major cell index by skipping the 7 void cells
with an ascending ``cell += (cell >= void)`` ladder.  Adding a new geometry
to the kernels is the same one-file registration pattern as the scalar tiers
in ``core/maps``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import msimplex as ms
from repro.core.domains import (
    EMBEDDED_FRACTAL_DOMAINS, MENGER_VOIDS, MSIMPLEX_MS,
)
from repro.core.registry import register_map

_MENGER_VOID_CELLS = sorted(9 * x + 3 * y + z for x, y, z in MENGER_VOIDS)


def _vec_isqrt(v):
    """Exact vectorized isqrt for int32 v (fp32 seed + correction ladder)."""
    r = jnp.sqrt(v.astype(jnp.float32)).astype(jnp.int32)
    for _ in range(3):
        r = jnp.where((r + 1) * (r + 1) <= v, r + 1, r)
        r = jnp.where(r * r > v, r - 1, r)
    return jnp.maximum(r, 0)


def _tri_xy(lam):
    x = (_vec_isqrt(8 * lam + 1) - 1) // 2
    return x, lam - x * (x + 1) // 2


def _tet_z(lam):
    z = jnp.cbrt(6.0 * lam.astype(jnp.float32)).astype(jnp.int32)
    for _ in range(3):
        z = jnp.where((z + 1) * (z + 2) * (z + 3) // 6 <= lam, z + 1, z)
        z = jnp.where((z > 0) & (z * (z + 1) * (z + 2) // 6 > lam), z - 1, z)
    return jnp.maximum(z, 0)


# ---------------------------------------------------------------------------
# Dense domains
# ---------------------------------------------------------------------------


@register_map("tri2d", "analytical", tier="pallas")
def tri2d_coords(lam, ndigits):
    del ndigits
    x, y = _tri_xy(lam)
    return [x, y]


@register_map("tri2d", "analytical", tier="membership")
def tri2d_membership(axes, ndigits):
    del ndigits
    x, y = axes
    return y <= x


@register_map("pyramid3d", "analytical", tier="pallas")
def pyramid3d_coords(lam, ndigits):
    del ndigits
    z = _tet_z(lam)
    rem = lam - z * (z + 1) * (z + 2) // 6
    x, y = _tri_xy(rem)
    return [x, y, z]


@register_map("pyramid3d", "analytical", tier="membership")
def pyramid3d_membership(axes, ndigits):
    del ndigits
    x, y, z = axes
    return (y <= x) & (x <= z)


# ---------------------------------------------------------------------------
# Fractal domains
# ---------------------------------------------------------------------------


@register_map("gasket2d", "bitwise", tier="pallas")
def gasket2d_coords(lam, ndigits):
    x = jnp.zeros_like(lam)
    y = jnp.zeros_like(lam)
    m, s = lam, 1
    for _ in range(ndigits):
        d = m % 3
        x += jnp.where(d == 1, s, 0)
        y += jnp.where(d == 2, s, 0)
        m, s = m // 3, s * 2
    return [x, y]


@register_map("gasket2d", "bitwise", tier="membership")
def gasket2d_membership(axes, ndigits):
    del ndigits
    x, y = axes
    return (x & y) == 0


@register_map("carpet2d", "bitwise", tier="pallas")
def carpet2d_coords(lam, ndigits):
    x = jnp.zeros_like(lam)
    y = jnp.zeros_like(lam)
    m, s = lam, 1
    for _ in range(ndigits):
        d = m % 8
        cell = d + (d >= 4).astype(jnp.int32)   # skip the (1,1) void
        x += (cell // 3) * s
        y += (cell % 3) * s
        m, s = m // 8, s * 3
    return [x, y]


@register_map("carpet2d", "bitwise", tier="membership")
def carpet2d_membership(axes, ndigits):
    x, y = axes
    ok = jnp.ones(x.shape, dtype=bool)
    for _ in range(ndigits):
        ok &= ~((x % 3 == 1) & (y % 3 == 1))
        x, y = x // 3, y // 3
    return ok


@register_map("sierpinski3d", "bitwise", tier="pallas")
def sierpinski3d_coords(lam, ndigits):
    x = jnp.zeros_like(lam)
    y = jnp.zeros_like(lam)
    z = jnp.zeros_like(lam)
    m, s = lam, 1
    for _ in range(ndigits):
        d = m % 4
        x += jnp.where(d == 1, s, 0)
        y += jnp.where(d == 2, s, 0)
        z += jnp.where(d == 3, s, 0)
        m, s = m // 4, s * 2
    return [x, y, z]


@register_map("sierpinski3d", "bitwise", tier="membership")
def sierpinski3d_membership(axes, ndigits):
    del ndigits
    x, y, z = axes
    return ((x & y) | (x & z) | (y & z)) == 0


@register_map("menger3d", "bitwise", tier="pallas")
def menger3d_coords(lam, ndigits):
    x = jnp.zeros_like(lam)
    y = jnp.zeros_like(lam)
    z = jnp.zeros_like(lam)
    m, s = lam, 1
    for _ in range(ndigits):
        cell = m % 20
        for void in _MENGER_VOID_CELLS:   # ascending skip ladder
            cell += (cell >= void).astype(jnp.int32)
        x += (cell // 9) * s
        y += ((cell // 3) % 3) * s
        z += (cell % 3) * s
        m, s = m // 20, s * 3
    return [x, y, z]


@register_map("menger3d", "bitwise", tier="membership")
def menger3d_membership(axes, ndigits):
    x, y, z = axes
    ok = jnp.ones(x.shape, dtype=bool)
    for _ in range(ndigits):
        ones = ((x % 3 == 1).astype(jnp.int32) + (y % 3 == 1) + (z % 3 == 1))
        ok &= ones < 2
        x, y, z = x // 3, y // 3, z // 3
    return ok


# ---------------------------------------------------------------------------
# m-simplex family: vectorized m-th-root layer peel (generalizes _vec_isqrt /
# _tet_z — fp32 seed + exact int32 correction ladder, one peel per level).
# The peel itself is the module-generic implementation in core/msimplex.py
# (shared with the numpy/jnp tiers), instantiated here with jax.numpy.
# ---------------------------------------------------------------------------


def _register_msimplex_tiers(m: int):
    def coords(lam, ndigits, _m=m):
        del ndigits  # closed-form per level; digits are a fractal concept
        rem = lam
        axes = []
        for level in range(_m, 0, -1):
            x = ms.vec_simplex_layer(jnp, rem, level)
            axes.append(x)
            rem = rem - ms.vec_simplex_size(jnp, x, level)
        return list(reversed(axes))

    def membership(axes, ndigits):
        del ndigits
        ok = axes[0] >= 0
        for lo, hi in zip(axes, axes[1:]):
            ok &= lo <= hi
        return ok

    register_map(f"msimplex{m}", "analytical",
                 tiers={"pallas": coords, "membership": membership})


for _m in MSIMPLEX_MS:
    _register_msimplex_tiers(_m)


# ---------------------------------------------------------------------------
# Embedded-2D-fractal family: generic digit engine driven by the domain's
# generator table (arithmetic where-ladders — no gathers), so a new family
# member needs no kernel code at all.
# ---------------------------------------------------------------------------


def _register_embedded_fractal_tiers(domain):
    base, scale, dim = domain.base, domain.scale, domain.dim
    vecs = tuple(tuple(int(x) for x in v) for v in domain.vecs)
    cell_codes = [sum(v[k] * scale ** (dim - 1 - k) for k in range(dim))
                  for v in vecs]

    def coords(lam, ndigits):
        axes = [jnp.zeros_like(lam) for _ in range(dim)]
        m, s = lam, 1
        for _ in range(ndigits):
            d = m % base
            for k in range(dim):
                for digit, v in enumerate(vecs):
                    if v[k]:
                        axes[k] += jnp.where(d == digit, v[k] * s, 0)
            m, s = m // base, s * scale
        return axes

    def membership(axes, ndigits):
        ok = jnp.ones(axes[0].shape, dtype=bool)
        cur = list(axes)
        for _ in range(ndigits):
            code = jnp.zeros_like(cur[0])
            for k in range(dim):
                code = code * scale + cur[k] % scale
            hit = jnp.zeros_like(ok)
            for c in cell_codes:
                hit |= code == c
            ok &= hit
            cur = [a // scale for a in cur]
        return ok

    register_map(domain.name, "bitwise",
                 tiers={"pallas": coords, "membership": membership})


for _dom in EMBEDDED_FRACTAL_DOMAINS:
    _register_embedded_fractal_tiers(_dom)
