"""Jit'd wrappers for the domain-map kernels + block-waste accounting.

Every entry point takes a *map spec* — a domain name, a ``Domain``, a
registry ``MapEntry`` or a validated ``MappingArtifact`` — and resolves the
geometry through the MapRegistry.

Execution routes through :mod:`repro.core.compile_cache`: the Pallas call
is traced and compiled once per ``(spec identity, shape, block_n, ndigits,
interpret, device)`` and every repeat invocation reuses the compiled
executable — the hot path is one cache hit plus the device dispatch, no
re-trace.  Pass ``compile_cache=None`` to bypass (the pre-cache behavior,
one trace per call); pass a :class:`~repro.core.compile_cache.CompileCache`
to use a private cache instead of the process default.
"""
from __future__ import annotations

import numpy as np

from repro.core import compile_cache as cc
from repro.core.artifact import resolve_domain
from repro.core.domains import get_domain
from repro.kernels.domain_map.kernel import build_map_call, build_membership_call


def _pad_to(n: int, m: int) -> int:
    return -(-n // m) * m


def map_plan(spec, n_points: int, block_n: int,
             start: int = 0) -> tuple[object, int, int]:
    """(domain, padded N, ndigits) for a mapped-kernel launch — shared by
    the local wrappers here and the batching EvaluationService, so both
    resolve identical executables for identical queries."""
    d = get_domain(resolve_domain(spec))
    padded = _pad_to(n_points, block_n)
    ndigits = max(d.level_for_points(start + padded), 1) \
        if d.kind == "fractal" else 13
    return d, padded, ndigits


def membership_plan(spec, extent: tuple[int, ...],
                    block_n: int) -> tuple[object, int, int]:
    """(domain, padded box total, ndigits) for a BB-membership launch."""
    d = get_domain(resolve_domain(spec))
    total = int(np.prod(extent))
    padded = _pad_to(total, block_n)
    # membership of the box needs digits covering the box extent
    ndigits = (max(d.level_for_points(total), 1) + 1) \
        if d.kind == "fractal" else 13
    return d, padded, ndigits


def mapped_executable(spec, padded: int, block_n: int, ndigits: int,
                      interpret: bool, start: int = 0,
                      compile_cache=cc.USE_DEFAULT):
    """The (cached) compiled executable for one mapped-kernel launch."""
    cache = cc.resolve(compile_cache)

    def build():
        return build_map_call(spec, padded, block_n, ndigits, interpret,
                              lam_offset=start)

    if cache is None:
        return build()
    key = cc.ExecKey(cc.spec_fingerprint(spec), "map",
                     (start, padded), block_n, ndigits,
                     interpret=interpret)
    return cache.get(key, build)


def membership_executable(spec, extent: tuple[int, ...], padded: int,
                          block_n: int, ndigits: int, interpret: bool,
                          compile_cache=cc.USE_DEFAULT):
    """The (cached) compiled executable for one BB-membership launch."""
    cache = cc.resolve(compile_cache)

    def build():
        return build_membership_call(spec, extent, block_n, ndigits,
                                     interpret, padded_total=padded)

    if cache is None:
        return build()
    key = cc.ExecKey(cc.spec_fingerprint(spec), "membership",
                     tuple(extent) + (padded,), block_n, ndigits,
                     interpret=interpret)
    return cache.get(key, build)


def map_coordinates(spec, n_points: int, block_n: int = 1024,
                    interpret: bool = False, start: int = 0,
                    compile_cache=cc.USE_DEFAULT) -> np.ndarray:
    """Coordinates for λ in [start, start + n_points) via the mapped-grid
    Pallas kernel, (N, dim).  ``start=0`` is the classic first-N launch."""
    d, padded, ndigits = map_plan(spec, n_points, block_n, start)
    call = mapped_executable(spec, padded, block_n, ndigits, interpret,
                             start=start, compile_cache=compile_cache)
    out = np.asarray(call())            # (8, padded)
    return out[: d.dim, :n_points].T    # (N, dim)


def bb_membership(spec, extent: tuple[int, ...],
                  block_n: int = 1024, interpret: bool = False,
                  compile_cache=cc.USE_DEFAULT) -> np.ndarray:
    """Row-major membership mask over the bounding box via the BB kernel."""
    d, padded, ndigits = membership_plan(spec, extent, block_n)
    total = int(np.prod(extent))
    call = membership_executable(spec, tuple(extent), padded, block_n,
                                 ndigits, interpret,
                                 compile_cache=compile_cache)
    out = np.asarray(call())[0]
    return out[:total]


def block_counts(spec, n_points: int, block_n: int = 256) -> dict:
    """Grid-step accounting for mapped vs bounding-box strategies."""
    d = get_domain(resolve_domain(spec))
    mapped_steps = -(-n_points // block_n)
    ext = d.bounding_box_extent(n_points)
    bb_steps = -(-int(np.prod(ext)) // block_n)
    return {
        "mapped_steps": mapped_steps,
        "bb_steps": bb_steps,
        "wasted_steps": bb_steps - mapped_steps,
        "waste_fraction": (bb_steps - mapped_steps) / bb_steps if bb_steps else 0.0,
    }
