"""Jit'd wrappers for the domain-map kernels + block-waste accounting.

Every entry point takes a *map spec* — a domain name, a ``Domain``, a
registry ``MapEntry`` or a validated ``MappingArtifact`` — and resolves the
geometry through the MapRegistry.
"""
from __future__ import annotations

import numpy as np

from repro.core.artifact import resolve_domain
from repro.core.domains import get_domain
from repro.kernels.domain_map.kernel import build_map_call, build_membership_call


def _pad_to(n: int, m: int) -> int:
    return -(-n // m) * m


def map_coordinates(spec, n_points: int, block_n: int = 1024,
                    interpret: bool = False) -> np.ndarray:
    """First n_points coordinates via the mapped-grid Pallas kernel, (N, dim)."""
    d = get_domain(resolve_domain(spec))
    padded = _pad_to(n_points, block_n)
    ndigits = max(d.level_for_points(padded), 1) if d.kind == "fractal" else 13
    call = build_map_call(spec, padded, block_n, ndigits, interpret)
    out = np.asarray(call())            # (8, padded)
    return out[: d.dim, :n_points].T    # (N, dim)


def bb_membership(spec, extent: tuple[int, ...],
                  block_n: int = 1024, interpret: bool = False) -> np.ndarray:
    """Row-major membership mask over the bounding box via the BB kernel."""
    d = get_domain(resolve_domain(spec))
    total = int(np.prod(extent))
    padded = _pad_to(total, block_n)
    # membership of the box needs digits covering the box extent
    ndigits = (max(d.level_for_points(total), 1) + 1) if d.kind == "fractal" else 13
    call = build_membership_call(spec, extent, block_n, ndigits, interpret,
                                 padded_total=padded)
    out = np.asarray(call())[0]
    return out[:total]


def block_counts(spec, n_points: int, block_n: int = 256) -> dict:
    """Grid-step accounting for mapped vs bounding-box strategies."""
    d = get_domain(resolve_domain(spec))
    mapped_steps = -(-n_points // block_n)
    ext = d.bounding_box_extent(n_points)
    bb_steps = -(-int(np.prod(ext)) // block_n)
    return {
        "mapped_steps": mapped_steps,
        "bb_steps": bb_steps,
        "wasted_steps": bb_steps - mapped_steps,
        "waste_fraction": (bb_steps - mapped_steps) / bb_steps if bb_steps else 0.0,
    }
