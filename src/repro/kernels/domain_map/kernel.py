"""Pallas kernels for block-space map evaluation and BB membership filtering.

TPU adaptation of the paper's deployment kernels (Sec. V.C): TPUs have no
global atomics, so the representative memory-bound workload is the map
evaluation itself — each grid step turns a VMEM block of linear indices
λ into domain coordinates, fully vectorized on the VPU (integer ALU ops
only, zero MXU traffic):

  * ``map_kernel``        — mapped strategy: grid of exactly ceil(N/bn) steps.
  * ``membership_kernel`` — bounding-box strategy: grid over the *box*
    (ceil(prod(extent)/bn) steps), evaluating the discard `if` per element.

The per-domain geometry (Table-I logic) is resolved through the MapRegistry's
``pallas``/``membership`` tiers (see ``geometry.py``); builders accept a
domain name, a ``Domain``, a registry ``MapEntry`` or a validated
``MappingArtifact`` — the artifact path is the paper's Phase-4 integration:
the validation report licenses deploying the registered exact kernel.

Output layout is (8, N) int32 — row r holds coordinate axis r (rows dim..7
are zero padding to match the TPU's (8, 128) int32 sublane tiling).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.artifact import resolve_spec
from repro.core.registry import REGISTRY
from repro.kernels.domain_map import geometry  # noqa: F401 — registers tiers


def _geometry_tier(spec, tier_name: str):
    """(domain, tier callable) for a map spec.

    A spec carrying a logic class (MapEntry, artifact) uses that entry's
    in-kernel tier when it registered one; otherwise it falls back to the
    domain's ground-truth geometry — the in-kernel map is per-domain
    geometry, and variant logic classes only differ in scalar cost model."""
    domain_name, logic = resolve_spec(spec)
    if logic is not None:
        try:
            entry = REGISTRY.resolve(domain_name, logic)
        except KeyError:  # e.g. an artifact's inferred cost class has no entry
            entry = None
        if entry is not None and tier_name in entry.tiers:
            return domain_name, entry.tiers[tier_name]
    return domain_name, REGISTRY.tier(domain_name, None, tier_name)


def _map_kernel(o_ref, *, coords_fn, block_n: int, ndigits: int,
                lam_offset: int = 0):
    pid = pl.program_id(0)
    lam = (lam_offset + pid * block_n
           + jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1))
    axes = coords_fn(lam, ndigits)
    out = jnp.concatenate(
        axes + [jnp.zeros_like(lam)] * (8 - len(axes)), axis=0
    )  # (8, bn)
    o_ref[...] = out


def _membership_kernel(o_ref, *, membership_fn, block_n: int,
                       extent: tuple[int, ...], ndigits: int):
    pid = pl.program_id(0)
    lam = pid * block_n + jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
    # row-major unravel over the box, for any dimensionality
    strides = [1] * len(extent)
    for k in range(len(extent) - 2, -1, -1):
        strides[k] = strides[k + 1] * extent[k + 1]
    axes = [(lam // s) % e for s, e in zip(strides, extent)]
    ok = membership_fn(axes, ndigits)
    o_ref[...] = ok.astype(jnp.int32)


def build_map_call(spec, n_points: int, block_n: int = 1024,
                   ndigits: int = 13, interpret: bool = False,
                   lam_offset: int = 0):
    """Zero-arg thunk evaluating coordinates for the λ-range
    ``[lam_offset, lam_offset + n_points)`` — offset 0 is the classic
    first-N launch; nonzero offsets serve range queries and per-device
    shards of a large sweep."""
    assert n_points % block_n == 0, "pad N to a block multiple"
    _, coords_fn = _geometry_tier(spec, "pallas")
    grid = (n_points // block_n,)
    kernel = functools.partial(
        _map_kernel, coords_fn=coords_fn, block_n=block_n, ndigits=ndigits,
        lam_offset=lam_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[],
        out_specs=pl.BlockSpec((8, block_n), lambda pid: (0, pid)),
        out_shape=jax.ShapeDtypeStruct((8, n_points), jnp.int32),
        interpret=interpret,
    )


def build_membership_call(spec, extent: tuple[int, ...],
                          block_n: int = 1024, ndigits: int = 13,
                          interpret: bool = False,
                          padded_total: int | None = None):
    total = 1
    for e in extent:
        total *= e
    total = padded_total if padded_total is not None else total
    assert total % block_n == 0, "pad the box to a block multiple"
    _, membership_fn = _geometry_tier(spec, "membership")
    grid = (total // block_n,)
    kernel = functools.partial(
        _membership_kernel, membership_fn=membership_fn, block_n=block_n,
        extent=extent, ndigits=ndigits,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[],
        out_specs=pl.BlockSpec((1, block_n), lambda pid: (0, pid)),
        out_shape=jax.ShapeDtypeStruct((1, total), jnp.int32),
        interpret=interpret,
    )
