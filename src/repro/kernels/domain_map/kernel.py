"""Pallas kernels for block-space map evaluation and BB membership filtering.

TPU adaptation of the paper's deployment kernels (Sec. V.C): TPUs have no
global atomics, so the representative memory-bound workload is the map
evaluation itself — each grid step turns a VMEM block of linear indices
λ into domain coordinates using the Table-I logic, fully vectorized on the
VPU (integer ALU ops only, zero MXU traffic):

  * ``map_kernel``        — mapped strategy: grid of exactly ceil(N/bn) steps.
  * ``membership_kernel`` — bounding-box strategy: grid over the *box*
    (ceil(prod(extent)/bn) steps), evaluating the discard `if` per element.

All digit→vector tables are evaluated arithmetically (no gathers): e.g. the
Menger digit d maps to the row-major cell index by skipping the 7 void cells
with an ascending `cell += (cell >= void)` ladder.

Output layout is (8, N) int32 — row r holds coordinate axis r (rows dim..7
are zero padding to match the TPU's (8, 128) int32 sublane tiling).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.domains import MENGER_VOIDS

_MENGER_VOID_CELLS = sorted(9 * x + 3 * y + z for x, y, z in MENGER_VOIDS)


def _vec_isqrt(v):
    """Exact vectorized isqrt for int32 v (fp32 seed + correction ladder)."""
    r = jnp.sqrt(v.astype(jnp.float32)).astype(jnp.int32)
    for _ in range(3):
        r = jnp.where((r + 1) * (r + 1) <= v, r + 1, r)
        r = jnp.where(r * r > v, r - 1, r)
    return jnp.maximum(r, 0)


def _tri_xy(lam):
    x = (_vec_isqrt(8 * lam + 1) - 1) // 2
    return x, lam - x * (x + 1) // 2


def _tet_z(lam):
    z = jnp.cbrt(6.0 * lam.astype(jnp.float32)).astype(jnp.int32)
    for _ in range(3):
        z = jnp.where((z + 1) * (z + 2) * (z + 3) // 6 <= lam, z + 1, z)
        z = jnp.where((z > 0) & (z * (z + 1) * (z + 2) // 6 > lam), z - 1, z)
    return jnp.maximum(z, 0)


def _coords_for(domain_name: str, lam, ndigits: int):
    """Vectorized Table-I map; lam is an int32 array, returns list of axes."""
    if domain_name == "tri2d":
        x, y = _tri_xy(lam)
        return [x, y]
    if domain_name == "pyramid3d":
        z = _tet_z(lam)
        rem = lam - z * (z + 1) * (z + 2) // 6
        x, y = _tri_xy(rem)
        return [x, y, z]
    if domain_name == "gasket2d":
        x = jnp.zeros_like(lam)
        y = jnp.zeros_like(lam)
        m, s = lam, 1
        for _ in range(ndigits):
            d = m % 3
            x += jnp.where(d == 1, s, 0)
            y += jnp.where(d == 2, s, 0)
            m, s = m // 3, s * 2
        return [x, y]
    if domain_name == "carpet2d":
        x = jnp.zeros_like(lam)
        y = jnp.zeros_like(lam)
        m, s = lam, 1
        for _ in range(ndigits):
            d = m % 8
            cell = d + (d >= 4).astype(jnp.int32)   # skip the (1,1) void
            x += (cell // 3) * s
            y += (cell % 3) * s
            m, s = m // 8, s * 3
        return [x, y]
    if domain_name == "sierpinski3d":
        x = jnp.zeros_like(lam)
        y = jnp.zeros_like(lam)
        z = jnp.zeros_like(lam)
        m, s = lam, 1
        for _ in range(ndigits):
            d = m % 4
            x += jnp.where(d == 1, s, 0)
            y += jnp.where(d == 2, s, 0)
            z += jnp.where(d == 3, s, 0)
            m, s = m // 4, s * 2
        return [x, y, z]
    if domain_name == "menger3d":
        x = jnp.zeros_like(lam)
        y = jnp.zeros_like(lam)
        z = jnp.zeros_like(lam)
        m, s = lam, 1
        for _ in range(ndigits):
            cell = m % 20
            for void in _MENGER_VOID_CELLS:   # ascending skip ladder
                cell += (cell >= void).astype(jnp.int32)
            x += (cell // 9) * s
            y += ((cell // 3) % 3) * s
            z += (cell % 3) * s
            m, s = m // 20, s * 3
        return [x, y, z]
    raise ValueError(domain_name)


def _membership(domain_name: str, axes, ndigits: int):
    """Vectorized `contains` — the BB kernel's discard condition."""
    if domain_name == "tri2d":
        x, y = axes
        return y <= x
    if domain_name == "pyramid3d":
        x, y, z = axes
        return (y <= x) & (x <= z)
    if domain_name == "gasket2d":
        x, y = axes
        return (x & y) == 0
    if domain_name == "sierpinski3d":
        x, y, z = axes
        return ((x & y) | (x & z) | (y & z)) == 0
    if domain_name == "carpet2d":
        x, y = axes
        ok = jnp.ones(x.shape, dtype=bool)
        for _ in range(ndigits):
            ok &= ~((x % 3 == 1) & (y % 3 == 1))
            x, y = x // 3, y // 3
        return ok
    if domain_name == "menger3d":
        x, y, z = axes
        ok = jnp.ones(x.shape, dtype=bool)
        for _ in range(ndigits):
            ones = ((x % 3 == 1).astype(jnp.int32) + (y % 3 == 1) + (z % 3 == 1))
            ok &= ones < 2
            x, y, z = x // 3, y // 3, z // 3
        return ok
    raise ValueError(domain_name)


def _map_kernel(o_ref, *, domain_name: str, block_n: int, ndigits: int):
    pid = pl.program_id(0)
    lam = pid * block_n + jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
    axes = _coords_for(domain_name, lam, ndigits)
    out = jnp.concatenate(
        axes + [jnp.zeros_like(lam)] * (8 - len(axes)), axis=0
    )  # (8, bn)
    o_ref[...] = out


def _membership_kernel(o_ref, *, domain_name: str, block_n: int,
                       extent: tuple[int, ...], ndigits: int):
    pid = pl.program_id(0)
    lam = pid * block_n + jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
    if len(extent) == 2:
        w = extent[1]
        axes = [lam // w, lam % w]
    else:
        h, w = extent[1], extent[2]
        axes = [lam // (h * w), (lam // w) % h, lam % w]
    ok = _membership(domain_name, axes, ndigits)
    o_ref[...] = ok.astype(jnp.int32)


def build_map_call(domain_name: str, n_points: int, block_n: int = 1024,
                   ndigits: int = 13, interpret: bool = False):
    assert n_points % block_n == 0, "pad N to a block multiple"
    grid = (n_points // block_n,)
    kernel = functools.partial(
        _map_kernel, domain_name=domain_name, block_n=block_n, ndigits=ndigits
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[],
        out_specs=pl.BlockSpec((8, block_n), lambda pid: (0, pid)),
        out_shape=jax.ShapeDtypeStruct((8, n_points), jnp.int32),
        interpret=interpret,
    )


def build_membership_call(domain_name: str, extent: tuple[int, ...],
                          block_n: int = 1024, ndigits: int = 13,
                          interpret: bool = False,
                          padded_total: int | None = None):
    total = 1
    for e in extent:
        total *= e
    total = padded_total if padded_total is not None else total
    assert total % block_n == 0, "pad the box to a block multiple"
    grid = (total // block_n,)
    kernel = functools.partial(
        _membership_kernel, domain_name=domain_name, block_n=block_n,
        extent=extent, ndigits=ndigits,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[],
        out_specs=pl.BlockSpec((1, block_n), lambda pid: (0, pid)),
        out_shape=jax.ShapeDtypeStruct((1, total), jnp.int32),
        interpret=interpret,
    )
