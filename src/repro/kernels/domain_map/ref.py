"""Pure-jnp/numpy oracles for the domain-map kernels."""
from __future__ import annotations

import numpy as np

from repro.core.artifact import resolve_domain
from repro.core.domains import get_domain
from repro.core.maps import np_map


def map_coordinates_ref(spec, n_points: int) -> np.ndarray:
    """(N, dim) coordinates of the first N domain points (mapped strategy)."""
    return np_map(resolve_domain(spec), np.arange(n_points, dtype=np.int64))


def bb_membership_ref(spec, extent: tuple[int, ...]) -> np.ndarray:
    """Row-major membership mask over the bounding box (BB strategy)."""
    d = get_domain(resolve_domain(spec))
    lam = np.arange(int(np.prod(extent)), dtype=np.int64)
    if d.dim == 2:
        w = extent[1]
        coords = np.stack([lam // w, lam % w], axis=-1)
    else:
        h, w = extent[1], extent[2]
        coords = np.stack([lam // (h * w), (lam // w) % h, lam % w], axis=-1)
    return d.contains(coords).astype(np.int32)
