"""Pure-jnp/numpy oracles for the domain-map kernels."""
from __future__ import annotations

import numpy as np

from repro.core.artifact import resolve_domain
from repro.core.domains import get_domain
from repro.core.maps import np_map


def map_coordinates_ref(spec, n_points: int) -> np.ndarray:
    """(N, dim) coordinates of the first N domain points (mapped strategy)."""
    return np_map(resolve_domain(spec), np.arange(n_points, dtype=np.int64))


def bb_membership_ref(spec, extent: tuple[int, ...]) -> np.ndarray:
    """Row-major membership mask over the bounding box (BB strategy)."""
    d = get_domain(resolve_domain(spec))
    lam = np.arange(int(np.prod(extent)), dtype=np.int64)
    coords = np.stack(np.unravel_index(lam, extent), axis=-1)
    return d.contains(coords).astype(np.int32)
