from repro.kernels.domain_map.ops import (  # noqa: F401
    bb_membership, block_counts, map_coordinates,
)
from repro.kernels.domain_map.ref import bb_membership_ref, map_coordinates_ref  # noqa: F401
