from repro.kernels.tri_attn.ops import causal_attention, tri_grid_size  # noqa: F401
from repro.kernels.tri_attn.ref import causal_attention_ref  # noqa: F401
