"""Causal flash attention with block-space thread mapping (the paper's
technique as a Pallas TPU kernel).

The (q_block i, k_block j) iteration space of causal attention is exactly the
paper's 2D lower-triangular domain.  Two grid strategies:

  * ``bounding_box`` — square grid (bh, nb, nb) with the invalid upper
    triangle discarded by ``pl.when(j <= i)``: the classic BB baseline of
    Fig. 1.  On TPU the grid is iterated *sequentially* per core, so the
    discarded nb(nb-1)/2 steps still pay grid-step + DMA-schedule overhead —
    the TPU equivalent of wasted CUDA blocks.
  * ``mapped`` — linear grid (bh, T(nb)) with T(nb) = nb(nb+1)/2 and the
    paper's Table-I inverse-triangular map evaluated *inside the BlockSpec
    index_map*:   i = (isqrt(8λ+1)-1)/2,  j = λ - i(i+1)/2.
    Zero wasted steps; ascending λ enumerates j = 0..i for each i, which is
    precisely the k-inner iteration order online softmax needs.

VMEM tiling: (block_q, head_dim) q tile, (block_k, head_dim) k/v tiles,
fp32 accumulators in VMEM scratch that persist across the sequential k steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _isqrt_fp32(v):
    """Exact integer sqrt for traced int32 scalars (index_map safe).

    float32 sqrt is 1-ulp accurate; the correction ladder restores exactness.
    lambda < T(nb) keeps 8λ+1 < 2^26 for nb <= 4096, where 1 correction step
    suffices — we apply two for margin.
    """
    r = jnp.sqrt(v.astype(jnp.float32)).astype(jnp.int32)
    for _ in range(2):
        r = jnp.where((r + 1) * (r + 1) <= v, r + 1, r)
        r = jnp.where(r * r > v, r - 1, r)
    return r


def lam_to_ij(lam):
    """The paper's 2D triangular map g(λ) = (i, j) on traced int scalars."""
    i = (_isqrt_fp32(8 * lam + 1) - 1) // 2
    j = lam - i * (i + 1) // 2
    return i, j


def _attn_kernel(
    q_ref, k_ref, v_ref,          # (1, bq, d) / (1, bk, d) VMEM tiles
    o_ref,                        # (1, bq, d) VMEM tile
    m_scr, l_scr, acc_scr,        # fp32 scratch carried across k steps
    *, scale: float, block_q: int, block_k: int, grid_mode: str,
):
    if grid_mode == "mapped":
        lam = pl.program_id(1)
        i, j = lam_to_ij(lam)
    else:
        i = pl.program_id(1)
        j = pl.program_id(2)

    def body():
        @pl.when(j == 0)
        def _init():
            m_scr[...] = jnp.full_like(m_scr, NEG_INF)
            l_scr[...] = jnp.zeros_like(l_scr)
            acc_scr[...] = jnp.zeros_like(acc_scr)

        q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        v = v_ref[0].astype(jnp.float32)                    # (bk, d)
        s = jax.lax.dot_general(                            # (bq, bk)
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        # diagonal blocks need the intra-block causal mask
        q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_prev = m_scr[...]                                 # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                     # rescale old state
        p = jnp.exp(s - m_new)                              # (bq, bk)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

        @pl.when(j == i)  # last valid k block for this q row — finalize
        def _finalize():
            o_ref[0] = (acc_scr[...] / l_scr[...]).astype(o_ref.dtype)

    if grid_mode == "bounding_box":
        pl.when(j <= i)(body)   # the paper's BB `if` discard
    else:
        body()


def tri_grid_size(nb: int) -> int:
    return nb * (nb + 1) // 2


def build_attention_call(
    batch_heads: int, seq: int, head_dim: int, *,
    block_q: int, block_k: int, grid_mode: str, dtype,
    interpret: bool = False,
):
    """Construct the pallas_call over a fused (batch*heads, seq, d) tensor."""
    assert seq % block_q == 0 and seq % block_k == 0
    assert block_q == block_k, "triangular block space needs square blocks"
    nb = seq // block_q
    scale = head_dim ** -0.5

    if grid_mode == "mapped":
        grid = (batch_heads, tri_grid_size(nb))

        def q_map(bh, lam):
            return (bh, lam_to_ij(lam)[0], 0)

        def kv_map(bh, lam):
            return (bh, lam_to_ij(lam)[1], 0)

        o_map = q_map
    elif grid_mode == "bounding_box":
        grid = (batch_heads, nb, nb)

        def q_map(bh, i, j):
            return (bh, i, 0)

        def kv_map(bh, i, j):
            # clamp the wasted upper-triangle steps onto a valid tile so the
            # discarded iterations don't fetch out-of-range blocks
            return (bh, jnp.minimum(j, i), 0)

        o_map = q_map
    else:
        raise ValueError(f"grid_mode {grid_mode!r}")

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_k=block_k,
        grid_mode=grid_mode,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, head_dim), q_map),
            pl.BlockSpec((1, block_k, head_dim), kv_map),
            pl.BlockSpec((1, block_k, head_dim), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, head_dim), o_map),
        out_shape=jax.ShapeDtypeStruct((batch_heads, seq, head_dim), dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, head_dim), jnp.float32),
        ],
        interpret=interpret,
    )
