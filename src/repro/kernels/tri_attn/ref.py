"""Pure-jnp oracle for causal (lower-triangular domain) attention."""
from __future__ import annotations

import jax.numpy as jnp


def causal_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, scale: float | None = None
) -> jnp.ndarray:
    """Reference causal attention.

    q, k, v: (batch, heads, seq, head_dim); returns same shape as q.
    Computation in float32 regardless of input dtype (kernel does the same).
    """
    *_, seq, head_dim = q.shape
    if scale is None:
        scale = head_dim ** -0.5
    qf = q.astype(jnp.float32) * scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, k.astype(jnp.float32))
    mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
