"""Jit'd public wrapper for the triangular-domain attention kernel.

`causal_attention(q, k, v)` accepts (batch, heads, seq, head_dim), handles
GQA by repeating kv heads, runs the Pallas forward, and differentiates via
the jnp oracle (custom_vjp) so the kernel is usable inside training graphs.
On CPU hosts `interpret=True` executes the kernel body in Python — the
correctness path used by tests; on TPU the same call compiles natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.tri_attn.kernel import build_attention_call, tri_grid_size  # noqa: F401
from repro.kernels.tri_attn.ref import causal_attention_ref


def _forward(q, k, v, *, block_q, block_k, grid_mode, interpret):
    b, h, s, d = q.shape
    hk = k.shape[1]
    if hk != h:  # GQA: repeat kv heads up to q heads
        assert h % hk == 0
        k = jnp.repeat(k, h // hk, axis=1)
        v = jnp.repeat(v, h // hk, axis=1)
    call = build_attention_call(
        b * h, s, d, block_q=block_q, block_k=block_k,
        grid_mode=grid_mode, dtype=q.dtype, interpret=interpret,
    )
    out = call(
        q.reshape(b * h, s, d), k.reshape(b * h, s, d), v.reshape(b * h, s, d)
    )
    return out.reshape(b, h, s, d)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def causal_attention(q, k, v, block_q=128, block_k=128, grid_mode="mapped",
                     interpret=False):
    """Causal attention over the lower-triangular block domain.

    grid_mode: "mapped" (linear λ grid, paper technique) or "bounding_box"
    (square grid + discard, paper baseline).
    """
    return _forward(q, k, v, block_q=block_q, block_k=block_k,
                    grid_mode=grid_mode, interpret=interpret)


def _fwd(q, k, v, block_q, block_k, grid_mode, interpret):
    out = _forward(q, k, v, block_q=block_q, block_k=block_k,
                   grid_mode=grid_mode, interpret=interpret)
    return out, (q, k, v)


def _bwd(block_q, block_k, grid_mode, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: causal_attention_ref(q_, k_, v_), q, k, v)
    return vjp(g)


causal_attention.defvjp(_fwd, _bwd)


def grid_steps(seq: int, block: int, grid_mode: str) -> int:
    """Sequential grid steps per (batch*head) — the waste accounting."""
    nb = seq // block
    return tri_grid_size(nb) if grid_mode == "mapped" else nb * nb
